//! PJRT integration: the AOT JAX/Pallas artifacts must agree with the
//! tuned native backend on every entry point.
//!
//! These tests need `artifacts/` (built by `make artifacts`); when it is
//! absent they skip with a note instead of failing, so `cargo test` works
//! on a fresh checkout.

use fastkmeanspp::data::matrix::PointSet;
use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::runtime::{native, pjrt::PjrtRuntime};

fn runtime() -> Option<PjrtRuntime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match PjrtRuntime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e:#}); run `make artifacts`");
            None
        }
    }
}

fn dataset(n: usize, d: usize, seed: u64) -> PointSet {
    gaussian_mixture(
        &SynthSpec {
            n,
            d,
            k_true: 12,
            center_spread: 10.0,
            ..Default::default()
        },
        seed,
    )
}

#[test]
fn cost_matches_native() {
    let Some(rt) = runtime() else { return };
    // n spans multiple chunks (2048-variant) + a tail; d=74 pads to 96.
    let ps = dataset(5000, 74, 1);
    let mut rng = Pcg64::seed_from(2);
    let centers = ps.gather(&(0..50).map(|_| rng.index(ps.len())).collect::<Vec<_>>());
    let native_cost = native::cost(&ps, &centers);
    let pjrt_cost = rt.cost(&ps, &centers).unwrap();
    let rel = (native_cost - pjrt_cost).abs() / native_cost.max(1.0);
    assert!(rel < 1e-3, "native={native_cost} pjrt={pjrt_cost} rel={rel}");
}

#[test]
fn assign_matches_native() {
    let Some(rt) = runtime() else { return };
    let ps = dataset(4500, 32, 3);
    let centers = ps.gather(&(0..30).collect::<Vec<_>>());
    let (ni, nd) = native::assign(&ps, &centers);
    let (pi, pd) = rt.assign(&ps, &centers).unwrap();
    assert_eq!(ni.len(), pi.len());
    let mut mismatched_idx = 0;
    for i in 0..ni.len() {
        // The matmul-form kernel (||x||^2 + ||c||^2 - 2xc) loses absolute
        // precision ~ |x|^2 * eps_f32 near zero distance; floor the
        // denominator at 1.0 (coordinates are O(10)).
        let rel = (nd[i] - pd[i]).abs() / nd[i].max(1.0);
        assert!(rel < 1e-2, "i={i} native_d2={} pjrt_d2={}", nd[i], pd[i]);
        if ni[i] != pi[i] {
            mismatched_idx += 1; // ties/eps may flip the argmin
        }
    }
    assert!(
        mismatched_idx < ni.len() / 100,
        "{mismatched_idx} argmin mismatches"
    );
}

#[test]
fn lloyd_step_matches_native() {
    let Some(rt) = runtime() else { return };
    let ps = dataset(6000, 68, 5);
    let centers = ps.gather(&(0..40).map(|i| i * 100).collect::<Vec<_>>());
    let (ns, nc, ncost) = native::lloyd_step(&ps, &centers);
    let (s, c, cost) = rt.lloyd_step(&ps, &centers).unwrap();
    assert_eq!(nc.len(), c.len());
    let total_native: u64 = nc.iter().sum();
    let total_pjrt: u64 = c.iter().sum();
    assert_eq!(total_native, ps.len() as u64);
    assert_eq!(total_pjrt, ps.len() as u64);
    // Counts may differ slightly on distance ties; sums must track.
    let mut count_diff = 0u64;
    for j in 0..nc.len() {
        count_diff += nc[j].abs_diff(c[j]);
    }
    assert!(count_diff < ps.len() as u64 / 100, "count diff {count_diff}");
    let rel = (ncost - cost).abs() / ncost.max(1.0);
    assert!(rel < 1e-3, "cost native={ncost} pjrt={cost}");
    let d = ps.dim();
    for j in 0..nc.len() {
        if nc[j] == c[j] {
            for t in 0..d {
                let a = ns[j * d + t];
                let b = s[j * d + t];
                assert!(
                    (a - b).abs() <= 1e-2 * a.abs().max(1.0),
                    "sum[{j},{t}] native={a} pjrt={b}"
                );
            }
        }
    }
}

#[test]
fn d2_update_matches_native() {
    let Some(rt) = runtime() else { return };
    let ps = dataset(5000, 90, 7);
    let center = ps.row(123).to_vec();
    let mut native_d2 = vec![f32::INFINITY; ps.len()];
    let mut pjrt_d2 = vec![f32::INFINITY; ps.len()];
    fastkmeanspp::seeding::kmeanspp::update_d2_parallel(&ps, 123, &mut native_d2);
    rt.d2_update(&ps, &center, &mut pjrt_d2).unwrap();
    for i in (0..ps.len()).step_by(37) {
        let rel = (native_d2[i] - pjrt_d2[i]).abs() / native_d2[i].max(1e-3);
        assert!(rel < 1e-2, "i={i} native={} pjrt={}", native_d2[i], pjrt_d2[i]);
    }
    // Second update with another center only decreases.
    let before = pjrt_d2.clone();
    rt.d2_update(&ps, &ps.row(4000).to_vec(), &mut pjrt_d2).unwrap();
    for i in 0..ps.len() {
        assert!(pjrt_d2[i] <= before[i] + 1e-6);
    }
}

#[test]
fn lloyd_full_runs_on_pjrt_backend() {
    let Some(_) = runtime() else { return };
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let backend = fastkmeanspp::runtime::Backend::auto(&dir);
    assert_eq!(backend.name(), "pjrt");
    let ps = dataset(4000, 16, 9);
    let mut rng = Pcg64::seed_from(10);
    let seed = fastkmeanspp::seeding::kmeanspp::kmeanspp(&ps, 10, &mut rng);
    let res = fastkmeanspp::lloyd::lloyd(
        &ps,
        &seed.centers,
        &fastkmeanspp::lloyd::LloydConfig {
            max_iters: 5,
            tol: 1e-9,
        },
        &backend,
    )
    .unwrap();
    for w in res.history.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-6), "{:?}", res.history);
    }
}
