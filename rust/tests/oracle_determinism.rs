//! Cross-thread-count bitwise determinism of the oracle-backed rejection
//! seeder — the ISSUE 5 acceptance leg that needs to own the environment.
//!
//! Discipline (same as `kernel_parity.rs` / `weighted_parity.rs`): this
//! target holds exactly ONE `#[test]`, because it mutates the
//! process-global `FKMPP_THREADS` and `FKMPP_KERNEL` variables and Cargo
//! runs `#[test]`s of one binary concurrently. Integration-test targets
//! are separate processes, so the mutation cannot leak into
//! `seeding_quality`/`oracle_semantics`.
//!
//! What makes the assertion hold (the contracts under test):
//!
//! * the acceptance loop draws from per-round proposal/acceptance RNG
//!   streams forked from the run seed — never from thread-dependent
//!   state;
//! * everything parallel on the init path (JL projection, tree builds,
//!   norm cache, MAXDIST reduction) is elementwise or fixed-block, hence
//!   thread-count-invariant by the kernel-engine contract;
//! * LSH hashing fans out over `parallel_map`, which is order-preserving
//!   and pure.
//!
//! `FKMPP_KERNEL=naive` is pinned so the kernel autotuner's timing
//! probes cannot flip dispatch between runs (the PR 3 cross-process
//! contract); the shapes here mostly sit below the probe floor anyway.

use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::seeding::rejection::{rejection_sampling, OracleKind, RejectionConfig};
use fastkmeanspp::seeding::Seeding;

#[test]
fn rejection_fixed_seed_bitwise_identical_across_thread_counts() {
    std::env::set_var("FKMPP_KERNEL", "naive");
    // d = 32 > the auto JL target, so the projection path (a parallel
    // kernel pass) is exercised; k = 150 > PREFIX_CAP (128), so LSH
    // queries leave the exact prefix and hit the bucket structures.
    let ps = gaussian_mixture(
        &SynthSpec {
            n: 4_000,
            d: 32,
            k_true: 12,
            center_spread: 15.0,
            ..Default::default()
        },
        31,
    );
    let k = 150;
    for oracle in OracleKind::all() {
        let cfg = RejectionConfig {
            oracle,
            ..Default::default()
        };
        let run = || -> Seeding {
            let mut rng = Pcg64::seed_from(33);
            rejection_sampling(&ps, k, &cfg, &mut rng)
        };
        let mut per_thread_count: Vec<Seeding> = Vec::new();
        for threads in ["1", "4"] {
            std::env::set_var("FKMPP_THREADS", threads);
            let a = run();
            let b = run();
            assert_eq!(a.k(), k, "{oracle:?} t={threads}");
            assert_eq!(
                a.indices, b.indices,
                "{oracle:?} t={threads}: same-seed repeat diverged"
            );
            assert_eq!(a.stats.proposals, b.stats.proposals, "{oracle:?} t={threads}");
            per_thread_count.push(a);
        }
        std::env::remove_var("FKMPP_THREADS");
        let (one, four) = (&per_thread_count[0], &per_thread_count[1]);
        assert_eq!(
            one.indices, four.indices,
            "{oracle:?}: thread count changed the chosen centers"
        );
        assert_eq!(
            one.centers, four.centers,
            "{oracle:?}: thread count changed the center bits"
        );
        assert_eq!(
            one.stats.proposals, four.stats.proposals,
            "{oracle:?}: thread count changed the proposal trace"
        );
        assert_eq!(
            one.stats.rejections, four.stats.rejections,
            "{oracle:?}: thread count changed the rejection trace"
        );
    }
    std::env::remove_var("FKMPP_KERNEL");
}
