//! Property tests for the **kernels v2** blocked norm-trick engine
//! (`rust/src/kernels/blocked.rs`) against the v1 scalar references:
//!
//! * dimensions d in {1, 3, 7, 8, 9, 16, 127, 128} — every remainder-lane
//!   configuration around the 8-lane block width;
//! * degenerate inputs: duplicate points, duplicate centers (exact ties),
//!   zero vectors, n < k;
//! * `FKMPP_THREADS` in {1, 4}, with blocked results (argmin, rescored
//!   distances, cost sums) required to be **bitwise identical** across
//!   thread counts — the PR 1 thread-invariance contract extended to the
//!   v2 accumulators;
//! * the `FKMPP_KERNEL=naive|blocked` dispatch override.
//!
//! Agreement contract: argmin **tie-breaking** is identical (bitwise-equal
//! computed distances resolve to the lowest center index — exercised via
//! duplicate centers, where the norm-trick values of the duplicates are
//! bitwise equal too). On random data a *near*-tie may round differently
//! under the two formulations, so where the argmins differ the two chosen
//! centers' direct distances must agree within a 1e-4 relative tolerance
//! — relative to the computation scale `‖x‖² + ‖c‖²`, the scale at which
//! the norm trick's cancellation error lives. Where the argmins agree the
//! v2 distance is asserted **bitwise equal** to v1 (v2 rescores winners
//! with the same scalar kernel).
//!
//! Everything lives in ONE test function: this binary owns both env vars
//! (same discipline as `kernel_parity.rs`).

use fastkmeanspp::data::matrix::{d2, PointSet};
use fastkmeanspp::kernels::{assign, blocked, norms, reduce};
use fastkmeanspp::rng::Pcg64;

/// Random points with injected degeneracies: one all-zeros row, one pair
/// of duplicate rows.
fn random_points(n: usize, d: usize, rng: &mut Pcg64) -> PointSet {
    let mut rows: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..d).map(|_| (rng.next_gaussian() * 10.0) as f32).collect())
        .collect();
    if n >= 2 {
        rows[n / 2] = vec![0.0; d]; // zero vector
    }
    if n >= 4 {
        let dup = rows[1].clone();
        rows[n - 1] = dup; // duplicate point
    }
    PointSet::from_rows(&rows)
}

/// v1 reference: scalar double loop, ascending center order, strict `<`.
fn naive_assign(ps: &PointSet, centers: &PointSet) -> (Vec<u32>, Vec<f32>) {
    let mut idx = vec![0u32; ps.len()];
    let mut mind2 = vec![f32::INFINITY; ps.len()];
    for i in 0..ps.len() {
        for j in 0..centers.len() {
            let dd = d2(ps.row(i), centers.row(j));
            if dd < mind2[i] {
                mind2[i] = dd;
                idx[i] = j as u32;
            }
        }
    }
    (idx, mind2)
}

fn naive_update_min(ps: &PointSet, center: &[f32], cur: &mut [f32]) {
    for i in 0..ps.len() {
        let dd = d2(ps.row(i), center);
        if dd < cur[i] {
            cur[i] = dd;
        }
    }
}

#[test]
fn blocked_kernels_match_v1_references() {
    const DIMS: [usize; 8] = [1, 3, 7, 8, 9, 16, 127, 128];
    // Per-(threads) collected fingerprints for the cross-thread bitwise
    // invariance check: (assign ids, assign d2s, cost sum) per case.
    let mut fingerprints: Vec<Vec<(Vec<u32>, Vec<f32>, f64)>> = Vec::new();

    for &threads in &[1usize, 4] {
        std::env::set_var("FKMPP_THREADS", threads.to_string());
        let mut case_prints = Vec::new();
        // Same seed for both thread counts: identical instances, so the
        // fingerprints are comparable bit-for-bit.
        let mut rng = Pcg64::seed_from(0x5EED_F00D);

        for &d in &DIMS {
            // Sizes straddle the kernels' inline/parallel cutoffs while
            // keeping the scalar reference affordable at d=128.
            let n = if d >= 127 { 1_400 } else { 4_600 };
            let ps = random_points(n, d, &mut rng);
            let pn = norms::squared_norms(&ps);

            // k sweep crosses the 8-lane and 32-center-tile boundaries.
            for &k in &[1usize, 7, 8, 9, 33, 40] {
                let centers = ps.gather(&(0..k).map(|_| rng.index(n)).collect::<Vec<_>>());
                let cn = norms::squared_norms(&centers);
                let ctx = format!("threads={threads} d={d} n={n} k={k}");

                let (gi, gd) = blocked::assign_argmin_blocked(&ps, &pn, &centers, &cn);
                let (wi, wd) = naive_assign(&ps, &centers);
                for i in 0..n {
                    let scale = pn[i] + cn[wi[i] as usize] + 1.0;
                    if gi[i] == wi[i] {
                        assert_eq!(gd[i], wd[i], "rescored distance {ctx} i={i}");
                    } else {
                        // Near-tie: both choices must be equally near.
                        assert!(
                            (gd[i] - wd[i]).abs() <= 1e-4 * scale,
                            "{ctx} i={i}: v2 center {} d2={} vs v1 center {} d2={}",
                            gi[i],
                            gd[i],
                            wi[i],
                            wd[i]
                        );
                    }
                    assert!(gd[i] >= 0.0, "negative distance {ctx} i={i}");
                }

                // Cost reduction (forced blocked): rescored sums must
                // match the v1 reference sum within the near-tie budget.
                std::env::set_var("FKMPP_KERNEL", "blocked");
                let got_cost = reduce::cost(&ps, &centers);
                std::env::remove_var("FKMPP_KERNEL");
                let want_cost: f64 = wd.iter().map(|&v| v as f64).sum();
                let cost_scale: f64 = pn.iter().map(|&v| v as f64).sum::<f64>() + 1.0;
                assert!(
                    (got_cost - want_cost).abs() <= 1e-4 * cost_scale,
                    "cost {ctx}: {got_cost} vs {want_cost}"
                );

                case_prints.push((gi, gd, got_cost));
            }

            // d2_update_min against a dataset row: norm-trick values agree
            // within the norm scale; the opened point's own slot is
            // EXACTLY zero (the norm-cache/dot-product identity).
            let center_idx = n / 3;
            let center = ps.row(center_idx).to_vec();
            let cnorm = blocked::dot(&center, &center);
            let mut got: Vec<f32> = (0..n).map(|_| rng.next_f32() * 500.0).collect();
            got[center_idx] = f32::INFINITY;
            let mut want = got.clone();
            blocked::d2_update_min_blocked(&ps, &center, &pn, &mut got);
            naive_update_min(&ps, &center, &mut want);
            for i in 0..n {
                let scale = pn[i] + cnorm + 1.0;
                assert!(
                    (got[i] - want[i]).abs() <= 1e-4 * scale,
                    "d2_update d={d} i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
                assert!(got[i] >= 0.0, "negative update d={d} i={i}");
            }
            assert_eq!(got[center_idx], 0.0, "self-distance must be exactly 0 (d={d})");
        }

        // Exact ties: a run of bitwise-duplicate centers (spanning
        // multiple lane groups and the tile boundary) must resolve to the
        // FIRST occurrence — identical to v1 — for every point.
        {
            let d = 9;
            let ps = random_points(300, d, &mut rng);
            let pn = norms::squared_norms(&ps);
            let template = ps.row(17).to_vec();
            let dup = PointSet::from_rows(&vec![template; 67]);
            let cn = norms::squared_norms(&dup);
            let (gi, gd) = blocked::assign_argmin_blocked(&ps, &pn, &dup, &cn);
            let (wi, wd) = naive_assign(&ps, &dup);
            assert_eq!(gi, wi, "duplicate-center tie-break (threads={threads})");
            assert!(gi.iter().all(|&j| j == 0), "all ties must pick index 0");
            assert_eq!(gd, wd, "tie distances are rescored => bitwise v1");
            assert_eq!(gd[17], 0.0, "the template point sits on the center");
        }

        // n < k: more centers than points (seeders clamp, kernels must not).
        {
            let d = 7;
            let ps = random_points(5, d, &mut rng);
            let pn = norms::squared_norms(&ps);
            let centers = ps.gather(&(0..17).map(|j| j % ps.len()).collect::<Vec<_>>());
            let cn = norms::squared_norms(&centers);
            let (gi, gd) = blocked::assign_argmin_blocked(&ps, &pn, &centers, &cn);
            let (wi, wd) = naive_assign(&ps, &centers);
            // Every point coincides with some center (gather repeats), so
            // distances are exactly zero and ties resolve identically.
            assert_eq!(gi, wi, "n<k tie-break (threads={threads})");
            assert_eq!(gd, wd);
            assert!(gd.iter().all(|&v| v == 0.0));
        }

        fingerprints.push(case_prints);
    }
    std::env::remove_var("FKMPP_THREADS");

    // Thread-count invariance of the v2 kernels: identical bits at 1 and
    // 4 threads — argmin, rescored distances AND the fixed-boundary cost
    // sums (f64 equality, not tolerance).
    assert_eq!(fingerprints[0].len(), fingerprints[1].len());
    for (c, (a, b)) in fingerprints[0].iter().zip(&fingerprints[1]).enumerate() {
        assert_eq!(a.0, b.0, "case {c}: argmin differs across thread counts");
        assert_eq!(a.1, b.1, "case {c}: distances differ across thread counts");
        assert_eq!(a.2.to_bits(), b.2.to_bits(), "case {c}: cost sum thread-variant");
    }

    // Dispatch override: FKMPP_KERNEL pins the implementation exactly.
    {
        let mut rng = Pcg64::seed_from(0xD15_BA7C4);
        let ps = random_points(2_000, 16, &mut rng);
        let centers = ps.gather(&(0..24).map(|_| rng.index(2_000)).collect::<Vec<_>>());
        let pn = norms::squared_norms(&ps);
        let cn = norms::squared_norms(&centers);

        std::env::set_var("FKMPP_KERNEL", "naive");
        let (ni, nd) = assign::assign_argmin(&ps, &centers);
        let (ri, rd) = assign::assign_argmin_naive(&ps, &centers);
        assert_eq!(ni, ri, "naive override must route to the v1 kernel");
        assert_eq!(nd, rd);

        std::env::set_var("FKMPP_KERNEL", "blocked");
        let (bi, bd) = assign::assign_argmin(&ps, &centers);
        let (vi, vd) = blocked::assign_argmin_blocked(&ps, &pn, &centers, &cn);
        assert_eq!(bi, vi, "blocked override must route to the v2 kernel");
        assert_eq!(bd, vd, "cached and on-the-fly norms must be the same bits");

        // The cached entry point with explicit norms: same bits again.
        let (ci, cd) = assign::assign_argmin_cached(&ps, Some(&pn), &centers, Some(&cn));
        assert_eq!(ci, vi);
        assert_eq!(cd, vd);
        std::env::remove_var("FKMPP_KERNEL");
    }
}
