//! Weighted-machinery parity suite (sharded-seeding PR):
//!
//! 1. **Unit-weight reduction**: weighted k-means++ with all weights = 1
//!    is **bitwise identical** to unweighted `kmeanspp` under the same
//!    seed — the contract that makes the k-means‖ recluster an honest
//!    generalization rather than a near-miss reimplementation.
//! 2. **Duplicated points ≍ integer weights**: weighting by `w` matches
//!    repeating a point `w` times, up to tree-sum slack.
//! 3. **Weighted-cost kernel parity**: `cost_weighted` matches a naive
//!    serial reference at `FKMPP_THREADS ∈ {1, 4}` (fixed-block f64
//!    reduction ⇒ thread-count-invariant bits).
//! 4. **Sharded-seeding invariance**: a full `kmeans_par` run returns
//!    bitwise-identical centers across thread counts AND shard counts —
//!    including with `FKMPP_KERNEL=blocked` pinned, which
//!    deterministically exercises the v2 path the global-shape dispatch
//!    exists to protect (unpinned, these shapes sit below the autotune
//!    work floor and always run v1).
//!
//! Env discipline (the `kernel_parity.rs` precedent): this binary has
//! exactly ONE `#[test]`, so it owns `FKMPP_THREADS` and `FKMPP_KERNEL`
//! with no cross-test interleaving.

use fastkmeanspp::data::matrix::{d2, PointSet};
use fastkmeanspp::kernels::reduce;
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::seeding::kmeanspp::kmeanspp;
use fastkmeanspp::shard::kmeanspar::{kmeans_par, KMeansParConfig};
use fastkmeanspp::shard::weighted::{weighted_kmeanspp, WeightedPointSet};

fn random_points(n: usize, d: usize, rng: &mut Pcg64) -> PointSet {
    let data: Vec<f32> = (0..n * d)
        .map(|_| (rng.next_gaussian() * 5.0) as f32)
        .collect();
    PointSet::from_flat(n, d, data)
}

fn unit_weights_reproduce_unweighted_kmeanspp_bitwise() {
    let mut shapes_rng = Pcg64::seed_from(0xD15C);
    for case in 0..6u64 {
        let n = 50 + shapes_rng.index(3_000);
        let d = 1 + shapes_rng.index(12);
        let k = 1 + shapes_rng.index(40).min(n - 1);
        let ps = random_points(n, d, &mut shapes_rng);
        let seed = 9_000 + case;

        let mut r_plain = Pcg64::seed_from(seed);
        let plain = kmeanspp(&ps, k, &mut r_plain);

        let mut r_weighted = Pcg64::seed_from(seed);
        let wps = WeightedPointSet::unit(ps.clone());
        let weighted = weighted_kmeanspp(&wps, k, &mut r_weighted);

        assert_eq!(
            weighted.indices, plain.indices,
            "case {case} (n={n} d={d} k={k}): index sequences diverged"
        );
        assert_eq!(
            weighted.centers, plain.centers,
            "case {case}: center rows diverged"
        );
        // Both engines must also leave the RNG in the same state — the
        // strongest form of "same code path".
        assert_eq!(
            r_plain.next_u64(),
            r_weighted.next_u64(),
            "case {case}: RNG streams diverged"
        );
    }
}

fn duplicated_points_match_integer_weights() {
    // Weighting a point by w must behave like repeating it w times:
    // compare weighted cost on the compact set vs plain cost on the
    // expanded set (up to the documented f64 tree-sum slack).
    let mut rng = Pcg64::seed_from(0xACED);
    let base = random_points(400, 6, &mut rng);
    let weights: Vec<f32> = (0..400).map(|i| 1.0 + (i % 4) as f32).collect();
    let mut expanded_rows = Vec::new();
    for i in 0..400 {
        for _ in 0..weights[i] as usize {
            expanded_rows.push(base.row(i).to_vec());
        }
    }
    let expanded = PointSet::from_rows(&expanded_rows);
    let centers = base.gather(&[0, 57, 200, 399]);
    let wps = WeightedPointSet::new(base.clone(), weights);
    let compact = fastkmeanspp::shard::weighted::weighted_cost(&wps, &centers);
    let full = reduce::cost(&expanded, &centers);
    assert!(
        (compact - full).abs() <= 1e-6 * full.max(1.0),
        "weighted cost {compact} vs expanded cost {full}"
    );
}

/// Weighted-cost kernel vs a naive serial reference, swept over
/// `FKMPP_THREADS ∈ {1, 4}`; the measured values must also agree
/// bitwise across the two sweeps.
fn weighted_cost_matches_serial_reference_across_thread_counts() {
    let mut results: Vec<Vec<f64>> = Vec::new();
    for &threads in &[1usize, 4] {
        std::env::set_var("FKMPP_THREADS", threads.to_string());
        let mut per_thread = Vec::new();
        let mut rng = Pcg64::seed_from(0xFEED ^ threads as u64);
        for case in 0..5 {
            let n = 1 + rng.index(7_000);
            let d = 1 + rng.index(16);
            let k = 1 + rng.index(30).min(n - 1);
            let ps = random_points(n, d, &mut rng);
            let centers = ps.gather(&(0..k).map(|_| rng.index(n)).collect::<Vec<_>>());
            let weights: Vec<f32> = (0..n).map(|_| rng.next_f32() * 3.0).collect();

            // Naive serial reference with the same scalar d2.
            let want: f64 = (0..n)
                .map(|i| {
                    let mut best = f32::INFINITY;
                    for j in 0..k {
                        best = best.min(d2(ps.row(i), centers.row(j)));
                    }
                    best as f64 * weights[i] as f64
                })
                .sum();
            let got = reduce::cost_weighted(&ps, &weights, &centers);
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "threads={threads} case={case} n={n} d={d} k={k}: {got} vs {want}"
            );
            per_thread.push(got);
        }
        results.push(per_thread);
    }
    // Fixed-boundary reduction: the kernel's bits must not move with the
    // thread count (same seeds → same instances in both sweeps).
    assert_eq!(results[0], results[1], "cost_weighted is thread-dependent");
}

/// `kmeans_par` must return bitwise-identical seedings across thread
/// counts and shard counts — on the default (autotuned, here always v1)
/// dispatch AND with the v2 blocked kernels pinned.
fn kmeans_par_invariant_across_threads_shards_and_kernels() {
    let mut gen = Pcg64::seed_from(0xBEAD);
    let ps = random_points(2_500, 8, &mut gen);
    let run = |shards: usize, seed: u64| {
        let cfg = KMeansParConfig {
            shards,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(seed);
        kmeans_par(&ps, 16, &cfg, &mut rng)
    };

    // Default dispatch (v1 at these shapes), threads x shards sweep.
    let mut runs = Vec::new();
    for &(threads, shards) in &[(1usize, 4usize), (4, 4), (4, 1), (1, 7)] {
        std::env::set_var("FKMPP_THREADS", threads.to_string());
        runs.push(run(shards, 0x5EED));
    }
    for r in &runs[1..] {
        assert_eq!(
            r.indices, runs[0].indices,
            "kmeans_par depends on the thread/shard layout (v1 path)"
        );
        assert_eq!(r.centers, runs[0].centers);
    }

    // Pinned v2: same sweep with FKMPP_KERNEL=blocked, so the blocked
    // update/assign cores run regardless of the autotune work floor —
    // the path the resolve-once-on-the-global-shape dispatch protects.
    std::env::set_var("FKMPP_KERNEL", "blocked");
    let mut v2_runs = Vec::new();
    for &(threads, shards) in &[(1usize, 1usize), (4, 4), (1, 7)] {
        std::env::set_var("FKMPP_THREADS", threads.to_string());
        v2_runs.push(run(shards, 0xB10C));
    }
    // Unit-weight parity must also hold while v2 is pinned (same-kernel
    // both sides — the parity argument is implementation-independent).
    let mut r_plain = Pcg64::seed_from(0x99);
    let plain = kmeanspp(&ps, 12, &mut r_plain);
    let mut r_weighted = Pcg64::seed_from(0x99);
    let weighted = weighted_kmeanspp(&WeightedPointSet::unit(ps.clone()), 12, &mut r_weighted);
    std::env::remove_var("FKMPP_KERNEL");
    std::env::remove_var("FKMPP_THREADS");
    for r in &v2_runs[1..] {
        assert_eq!(
            r.indices, v2_runs[0].indices,
            "kmeans_par depends on the thread/shard layout (blocked v2 path)"
        );
        assert_eq!(r.centers, v2_runs[0].centers);
    }
    assert_eq!(weighted.indices, plain.indices, "unit-weight parity under v2");
}

#[test]
fn weighted_parity_suite() {
    // This binary has exactly one test, so it owns both env vars.
    unit_weights_reproduce_unweighted_kmeanspp_bitwise();
    duplicated_points_match_integer_weights();
    weighted_cost_matches_serial_reference_across_thread_counts();
    kmeans_par_invariant_across_threads_shards_and_kernels();
    std::env::remove_var("FKMPP_THREADS");
}
