//! End-to-end serving-layer test: boots the `fkmpp serve` subsystem on
//! an ephemeral port, drives the full `POST /fit` → `GET /jobs/{id}` →
//! `POST /models/{id}/assign` lifecycle over real TCP with a raw HTTP/1.1
//! client, and asserts that served labels match a direct
//! `kernels::assign::assign_argmin` call **exactly** (the ISSUE 2
//! acceptance criterion).
//!
//! Exactness holds because the JSON layer's shortest-round-trip float
//! emitter makes `f32 → f64 → text → f64 → f32` bit-exact in both
//! directions, so the server computes on the same bits we do.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use fastkmeanspp::data::io::encode_fbin;
use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::kernels::assign::assign_argmin;
use fastkmeanspp::server::json::{self, Json};
use fastkmeanspp::server::registry::{ModelMeta, ModelRegistry};
use fastkmeanspp::server::{decode_assign_frame, ServeConfig, Server};

/// Minimal blocking HTTP client: one request, `Connection: close`, parse
/// status + JSON body.
fn http(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let body = body.unwrap_or("");
    let request = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(request.as_bytes()).expect("write request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {raw:?}"))
        .parse()
        .expect("status code");
    let split = raw.find("\r\n\r\n").expect("header/body split");
    let body = &raw[split + 4..];
    let parsed = if body.is_empty() {
        Json::Null
    } else {
        json::parse(body).unwrap_or_else(|e| panic!("bad JSON body {body:?}: {e:#}"))
    };
    (status, parsed)
}

/// Same raw client, but returns headers + body text unparsed (the
/// Prometheus exposition is not JSON).
fn http_text(addr: &str, path: &str) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let request = format!(
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(request.as_bytes()).expect("write request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {raw:?}"))
        .parse()
        .expect("status code");
    let split = raw.find("\r\n\r\n").expect("header/body split");
    (status, raw[..split].to_string(), raw[split + 4..].to_string())
}

/// Prometheus metric-name grammar: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn valid_prometheus_name(name: &str) -> bool {
    let mut chars = name.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':')
        && chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

#[test]
fn serve_fit_job_assign_roundtrip() {
    let dir = std::env::temp_dir().join("fkmpp_serve_e2e");
    let _ = std::fs::remove_dir_all(&dir);

    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0, // ephemeral
        data_dir: dir.clone(),
        artifacts_dir: "/nonexistent".into(),
        http_workers: 2,
        fit_workers: 1,
        persist: true,
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr").to_string();
    let server_thread = std::thread::spawn(move || server.run());

    // Liveness.
    let (status, health) = http(&addr, "GET", "/healthz", None);
    assert_eq!(status, 200, "{health:?}");
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));

    // Fit: inline points through the paper's rejection sampler + Lloyd.
    let train = gaussian_mixture(
        &SynthSpec {
            n: 400,
            d: 6,
            k_true: 5,
            ..Default::default()
        },
        11,
    );
    let fit_body = Json::obj(vec![
        ("points", json::points_to_json(&train)),
        ("algo", Json::str("rejection")),
        ("k", Json::num(5.0)),
        ("seed", Json::num(7.0)),
        ("lloyd", Json::num(2.0)),
    ])
    .emit();
    let (status, fit) = http(&addr, "POST", "/fit", Some(&fit_body));
    assert_eq!(status, 202, "{fit:?}");
    let job_id = fit
        .get("job_id")
        .and_then(Json::as_str)
        .expect("job_id")
        .to_string();

    // The job id comes back immediately; poll it to completion.
    let deadline = Instant::now() + Duration::from_secs(120);
    let model_id = loop {
        let (status, job) = http(&addr, "GET", &format!("/jobs/{job_id}"), None);
        assert_eq!(status, 200, "{job:?}");
        match job.get("state").and_then(Json::as_str) {
            Some("done") => {
                assert!(job.get("secs").and_then(Json::as_f64).unwrap() >= 0.0);
                break job
                    .get("model_id")
                    .and_then(Json::as_str)
                    .expect("model_id")
                    .to_string();
            }
            Some("failed") => panic!("fit failed: {job:?}"),
            _ => {
                assert!(Instant::now() < deadline, "fit did not finish in time");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    };

    // The model is listed and fully retrievable.
    let (status, models) = http(&addr, "GET", "/models", None);
    assert_eq!(status, 200);
    assert_eq!(models.get("count").and_then(Json::as_usize), Some(1));
    let (status, model) = http(&addr, "GET", &format!("/models/{model_id}"), None);
    assert_eq!(status, 200, "{model:?}");
    assert_eq!(model.get("algorithm").and_then(Json::as_str), Some("rejection"));
    let centers =
        json::points_from_json(model.get("centers").expect("centers")).expect("parse centers");
    assert_eq!(centers.len(), 5);
    assert_eq!(centers.dim(), 6);

    // Batched assignment through the server...
    let queries = gaussian_mixture(
        &SynthSpec {
            n: 120,
            d: 6,
            k_true: 5,
            ..Default::default()
        },
        23,
    );
    let assign_body = Json::obj(vec![("points", json::points_to_json(&queries))]).emit();
    let (status, assigned) = http(
        &addr,
        "POST",
        &format!("/models/{model_id}/assign"),
        Some(&assign_body),
    );
    assert_eq!(status, 200, "{assigned:?}");
    let labels: Vec<u32> = assigned
        .get("labels")
        .and_then(Json::as_array)
        .expect("labels")
        .iter()
        .map(|v| v.as_f64().expect("numeric label") as u32)
        .collect();
    let served_d2: Vec<f32> = assigned
        .get("d2")
        .and_then(Json::as_array)
        .expect("d2")
        .iter()
        .map(|v| v.as_f64().expect("numeric d2") as f32)
        .collect();

    // ...must exactly match the kernel engine on the same bits.
    let (want_labels, want_d2) = assign_argmin(&queries, &centers);
    assert_eq!(
        labels, want_labels,
        "served labels must match kernels::assign::assign_argmin exactly"
    );
    assert_eq!(served_d2, want_d2, "served distances must match the kernel");

    // Kernels-v2 satellite: the model's center-norm cache is computed
    // once at registration; repeated identical assign requests must
    // serve BYTE-identical label/distance vectors (no per-request
    // recomputation drift).
    let first_emit = (
        assigned.get("labels").expect("labels").emit(),
        assigned.get("d2").expect("d2").emit(),
    );
    for rep in 0..3 {
        let (status, again) = http(
            &addr,
            "POST",
            &format!("/models/{model_id}/assign"),
            Some(&assign_body),
        );
        assert_eq!(status, 200, "repeat {rep}: {again:?}");
        let emit = (
            again.get("labels").expect("labels").emit(),
            again.get("d2").expect("d2").emit(),
        );
        assert_eq!(emit, first_emit, "repeat {rep}: response must be byte-identical");
    }

    // Sharded-seeding satellite: a `kmeans_par` fit runs through the
    // shard engine and its round counters/timings surface at /metrics.
    let par_fit_body = Json::obj(vec![
        ("points", json::points_to_json(&train)),
        ("algorithm", Json::str("kmeans_par")),
        ("k", Json::num(5.0)),
        ("seed", Json::num(13.0)),
        ("shards", Json::num(2.0)),
        ("rounds", Json::num(3.0)),
        ("oversample", Json::num(2.0)),
    ])
    .emit();
    let (status, par_fit) = http(&addr, "POST", "/fit", Some(&par_fit_body));
    assert_eq!(status, 202, "{par_fit:?}");
    let par_job = par_fit
        .get("job_id")
        .and_then(Json::as_str)
        .expect("job_id")
        .to_string();
    let par_deadline = Instant::now() + Duration::from_secs(120);
    let par_model_id = loop {
        let (status, job) = http(&addr, "GET", &format!("/jobs/{par_job}"), None);
        assert_eq!(status, 200, "{job:?}");
        match job.get("state").and_then(Json::as_str) {
            Some("done") => {
                break job
                    .get("model_id")
                    .and_then(Json::as_str)
                    .expect("model_id")
                    .to_string()
            }
            Some("failed") => panic!("kmeans_par fit failed: {job:?}"),
            _ => {
                assert!(Instant::now() < par_deadline, "kmeans_par fit did not finish");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    };
    let (status, par_model) = http(&addr, "GET", &format!("/models/{par_model_id}"), None);
    assert_eq!(status, 200, "{par_model:?}");
    assert_eq!(
        par_model.get("algorithm").and_then(Json::as_str),
        Some("kmeans-par")
    );
    let (status, shard_metrics) = http(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let shard_rounds = shard_metrics
        .get("counters")
        .and_then(|c| c.get("shard.rounds"))
        .and_then(Json::as_usize)
        .unwrap_or(0);
    // The fit asked for 3 rounds (early-exit only if candidates cover
    // every point exactly, impossible on a Gaussian mixture with k=5).
    assert!(shard_rounds >= 3, "{shard_metrics:?}");
    assert!(
        shard_metrics
            .get("counters")
            .and_then(|c| c.get("shard.runs"))
            .and_then(Json::as_usize)
            .unwrap_or(0)
            >= 1,
        "{shard_metrics:?}"
    );
    assert!(
        shard_metrics
            .get("timings")
            .and_then(|t| t.get("shard.round_secs"))
            .and_then(|s| s.get("mean"))
            .is_some(),
        "{shard_metrics:?}"
    );

    // LSH-oracle satellite: a rejection fit with the oracle selected per
    // request runs end-to-end, and the oracle counters surface at
    // /metrics (the acceptance-loop flush to the process-wide sink).
    let lsh_fit_body = Json::obj(vec![
        ("points", json::points_to_json(&train)),
        ("algo", Json::str("rejection")),
        ("oracle", Json::str("lsh")),
        ("k", Json::num(5.0)),
        ("seed", Json::num(17.0)),
    ])
    .emit();
    let (status, lsh_fit) = http(&addr, "POST", "/fit", Some(&lsh_fit_body));
    assert_eq!(status, 202, "{lsh_fit:?}");
    let lsh_job = lsh_fit
        .get("job_id")
        .and_then(Json::as_str)
        .expect("job_id")
        .to_string();
    let lsh_deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, job) = http(&addr, "GET", &format!("/jobs/{lsh_job}"), None);
        assert_eq!(status, 200, "{job:?}");
        match job.get("state").and_then(Json::as_str) {
            Some("done") => break,
            Some("failed") => panic!("lsh-oracle fit failed: {job:?}"),
            _ => {
                assert!(Instant::now() < lsh_deadline, "lsh-oracle fit did not finish");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
    let (status, oracle_metrics) = http(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let counters = oracle_metrics.get("counters").expect("counters");
    for name in ["oracle.probes", "oracle.accepts", "oracle.rejects", "oracle.proposals"] {
        assert!(
            counters.get(name).and_then(Json::as_f64).is_some(),
            "{name} missing from {oracle_metrics:?}"
        );
    }
    // Two rejection fits ran (5 centers each): accepts reached at least 10.
    assert!(
        counters
            .get("oracle.accepts")
            .and_then(Json::as_usize)
            .unwrap_or(0)
            >= 10,
        "{oracle_metrics:?}"
    );
    assert!(
        oracle_metrics
            .get("timings")
            .and_then(|t| t.get("oracle.probe_secs"))
            .and_then(|s| s.get("mean"))
            .is_some(),
        "{oracle_metrics:?}"
    );
    // An unknown oracle name is a client error, not a queued-then-failed job.
    let (status, bad_oracle) = http(
        &addr,
        "POST",
        "/fit",
        Some(r#"{"points": [[1,2],[3,4]], "k": 1, "algo": "rejection", "oracle": "bogus"}"#),
    );
    assert_eq!(status, 400, "{bad_oracle:?}");

    // Error paths stay clean under load.
    let (status, _) = http(&addr, "GET", "/jobs/job-999", None);
    assert_eq!(status, 404);
    let (status, _) = http(&addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _) = http(&addr, "POST", "/fit", Some("not json"));
    assert_eq!(status, 400);

    // Metrics saw the traffic (three models now: rejection + kmeans_par
    // + the lsh-oracle rejection fit).
    let (status, metrics) = http(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert_eq!(metrics.get("models").and_then(Json::as_usize), Some(3));
    assert!(
        metrics
            .get("counters")
            .and_then(|c| c.get("http.requests"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
            >= 5.0,
        "{metrics:?}"
    );
    // 120 query points x (1 + 3 repeated) assign calls.
    assert!(
        metrics
            .get("counters")
            .and_then(|c| c.get("assign.points"))
            .and_then(Json::as_usize)
            == Some(480),
        "{metrics:?}"
    );
    // Observability satellite: request latency is a log-bucketed
    // histogram now — /metrics reports its p50/p99 (JSON side).
    let http_latency = metrics
        .get("timings")
        .and_then(|t| t.get("http.latency_secs"))
        .unwrap_or_else(|| panic!("no http.latency_secs in {metrics:?}"));
    for q in ["p50", "p99", "count", "mean"] {
        assert!(
            http_latency.get(q).and_then(Json::as_f64).is_some(),
            "{q} missing from http.latency_secs: {metrics:?}"
        );
    }

    // Prometheus exposition satellite: the same metrics as text/plain
    // v0.0.4, parsed line-by-line — every metric name obeys the grammar,
    // every histogram's cumulative buckets are monotone and agree with
    // its `_count` series.
    let (status, headers, prom) = http_text(&addr, "/metrics?format=prometheus");
    assert_eq!(status, 200, "{prom}");
    assert!(
        headers.to_ascii_lowercase().contains("text/plain; version=0.0.4"),
        "missing exposition content type in {headers:?}"
    );
    for needle in [
        "# TYPE fkmpp_http_latency_secs histogram",
        "fkmpp_http_latency_secs_bucket{le=\"+Inf\"}",
        "fkmpp_shard_rounds_total",
        "fkmpp_oracle_probe_secs_bucket",
    ] {
        assert!(prom.contains(needle), "{needle:?} missing from:\n{prom}");
    }
    let mut buckets: Vec<(String, String, u64)> = Vec::new(); // (metric, le, cum)
    let mut scalars: Vec<(String, f64)> = Vec::new();
    for line in prom.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (series, value) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("bad exposition line {line:?}"));
        if let Some((metric, rest)) = series.split_once("_bucket{le=\"") {
            let le = rest
                .strip_suffix("\"}")
                .unwrap_or_else(|| panic!("bad bucket label in {line:?}"));
            assert!(valid_prometheus_name(metric), "bad name in {line:?}");
            let cum: u64 = value.parse().unwrap_or_else(|_| panic!("bad count {line:?}"));
            buckets.push((metric.to_string(), le.to_string(), cum));
        } else {
            assert!(valid_prometheus_name(series), "bad name in {line:?}");
            let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value {line:?}"));
            scalars.push((series.to_string(), v));
        }
    }
    assert!(!buckets.is_empty(), "no histogram series in:\n{prom}");
    // Per-histogram: cumulative counts nondecreasing, le edges strictly
    // increasing, and the +Inf bucket equals the `_count` scalar.
    let metric_names: Vec<String> = {
        let mut v: Vec<String> = buckets.iter().map(|(m, _, _)| m.clone()).collect();
        v.dedup();
        v
    };
    for metric in &metric_names {
        let series: Vec<&(String, String, u64)> =
            buckets.iter().filter(|(m, _, _)| m == metric).collect();
        let mut last_cum = 0u64;
        let mut last_le = f64::NEG_INFINITY;
        let mut inf_cum = None;
        for (_, le, cum) in &series {
            assert!(*cum >= last_cum, "{metric}: non-monotone buckets:\n{prom}");
            last_cum = *cum;
            if le == "+Inf" {
                inf_cum = Some(*cum);
            } else {
                let edge: f64 = le.parse().unwrap_or_else(|_| panic!("bad le {le:?}"));
                assert!(edge > last_le, "{metric}: le edges not increasing");
                last_le = edge;
            }
        }
        let inf_cum = inf_cum.unwrap_or_else(|| panic!("{metric}: no +Inf bucket"));
        let count = scalars
            .iter()
            .find(|(n, _)| n == &format!("{metric}_count"))
            .unwrap_or_else(|| panic!("{metric}: no _count series"))
            .1;
        assert_eq!(inf_cum as f64, count, "{metric}: +Inf bucket != _count");
    }

    // Graceful shutdown drains the pools and run() returns Ok.
    let (status, _) = http(&addr, "POST", "/shutdown", None);
    assert_eq!(status, 200);
    server_thread
        .join()
        .expect("server thread")
        .expect("server run");

    // Persistence: a fresh registry over the same data dir reloads the
    // model bit-exactly (what a server restart would see).
    let reloaded = ModelRegistry::new(Some(dir)).expect("reload registry");
    let model = reloaded.get(&model_id).expect("model persisted");
    assert_eq!(model.centers, centers);
    assert_eq!(model.meta.k, 5);
}

/// Serialize one raw request. Empty `content_type` omits the header;
/// `close` adds `Connection: close` (otherwise HTTP/1.1 default applies).
fn raw_request(method: &str, path: &str, content_type: &str, body: &[u8], close: bool) -> Vec<u8> {
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: e2e\r\n");
    if !content_type.is_empty() {
        head.push_str(&format!("Content-Type: {content_type}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\n", body.len()));
    if close {
        head.push_str("Connection: close\r\n");
    }
    head.push_str("\r\n");
    let mut out = head.into_bytes();
    out.extend_from_slice(body);
    out
}

/// Read exactly ONE response off a kept-alive connection (the
/// `read_to_string` trick in [`http`] only works with
/// `Connection: close`). Returns status, lowercased headers, and the
/// Content-Length-sized body bytes.
fn read_one_response<R: BufRead>(reader: &mut R) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("read status line");
    assert!(n > 0, "connection closed before a response arrived");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status in {line:?}"))
        .parse()
        .expect("status code");
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        assert!(reader.read_line(&mut h).expect("read header") > 0, "EOF in headers");
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((name, value)) = t.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value.parse().expect("Content-Length");
            }
            headers.push((name, value));
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).expect("read body");
    (status, headers, body)
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| v.as_str())
}

/// ISSUE 8 tentpole leg: one socket carries many requests (JSON then
/// binary then a capped third), the binary route answers bit-identically
/// to the JSON route, and the protocol bugfixes (leading-CRLF skip,
/// conflicting duplicate Content-Length → written 400) hold on the wire.
#[test]
fn keep_alive_session_binary_parity_and_protocol_fixes() {
    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0,
        persist: false,
        http_workers: 2,
        fit_workers: 1,
        queue_depth: 16,
        keepalive_max_requests: 3,
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr");
    // Install a model directly — this leg tests the wire, not the fit.
    let reg = server.registry();
    let centers = gaussian_mixture(
        &SynthSpec {
            n: 4,
            d: 3,
            k_true: 2,
            ..Default::default()
        },
        5,
    );
    let meta = ModelMeta {
        id: reg.fresh_id(),
        version: 1,
        algorithm: "uniform".to_string(),
        k: 4,
        dim: 3,
        source: "test".to_string(),
        seed: 0,
        seeding_secs: 0.0,
        lloyd_iters: 0,
        cost: 0.0,
    };
    let model_id = meta.id.clone();
    reg.insert(meta, centers.clone()).expect("insert model");
    let server_thread = std::thread::spawn(move || server.run());

    let queries = gaussian_mixture(
        &SynthSpec {
            n: 17,
            d: 3,
            k_true: 2,
            ..Default::default()
        },
        6,
    );
    let assign_path = format!("/models/{model_id}/assign");

    // Three requests on ONE socket.
    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);

    // 1: JSON assign — HTTP/1.1 defaults to keep-alive, the server says so.
    let json_body = Json::obj(vec![("points", json::points_to_json(&queries))]).emit();
    writer
        .write_all(&raw_request(
            "POST",
            &assign_path,
            "application/json",
            json_body.as_bytes(),
            false,
        ))
        .unwrap();
    let (status, headers, body) = read_one_response(&mut reader);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    assert_eq!(header(&headers, "connection"), Some("keep-alive"), "{headers:?}");
    let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let json_labels: Vec<u32> = v
        .get("labels")
        .and_then(Json::as_array)
        .expect("labels")
        .iter()
        .map(|x| x.as_f64().expect("label") as u32)
        .collect();
    let json_d2_bits: Vec<u32> = v
        .get("d2")
        .and_then(Json::as_array)
        .expect("d2")
        .iter()
        .map(|x| (x.as_f64().expect("d2") as f32).to_bits())
        .collect();

    // 2: binary assign pipelined on the same socket — .fbin in, FKA1 out.
    writer
        .write_all(&raw_request(
            "POST",
            &assign_path,
            "application/octet-stream",
            &encode_fbin(&queries),
            false,
        ))
        .unwrap();
    let (status, headers, frame) = read_one_response(&mut reader);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&frame));
    assert_eq!(header(&headers, "connection"), Some("keep-alive"), "{headers:?}");
    assert_eq!(
        header(&headers, "content-type"),
        Some("application/octet-stream"),
        "{headers:?}"
    );
    let (bin_labels, bin_d2s) = decode_assign_frame(&frame).expect("FKA1 frame");
    // Byte-identical to the JSON route, and both match the kernel.
    assert_eq!(bin_labels, json_labels);
    let bin_d2_bits: Vec<u32> = bin_d2s.iter().map(|d| d.to_bits()).collect();
    assert_eq!(bin_d2_bits, json_d2_bits);
    let (want_labels, want_d2s) = assign_argmin(&queries, &centers);
    assert_eq!(bin_labels, want_labels);
    assert_eq!(
        bin_d2_bits,
        want_d2s.iter().map(|d| d.to_bits()).collect::<Vec<_>>()
    );

    // 3: the per-connection cap (3) closes the session, with notice.
    writer
        .write_all(&raw_request("GET", "/healthz", "", &[], false))
        .unwrap();
    let (status, headers, _) = read_one_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(header(&headers, "connection"), Some("close"), "{headers:?}");
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).expect("EOF after close");
    assert!(rest.is_empty(), "no bytes after Connection: close");

    // RFC 7230 §3.5 satellite: leading bare CRLFs before the request
    // line are skipped, on the real wire.
    let mut s2 = TcpStream::connect(addr).unwrap();
    s2.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s2.write_all(b"\r\n\r\n").unwrap();
    s2.write_all(&raw_request("GET", "/healthz", "", &[], true))
        .unwrap();
    let mut r2 = BufReader::new(s2);
    let (status, _, _) = read_one_response(&mut r2);
    assert_eq!(status, 200);

    // Smuggling-hazard satellite: conflicting duplicate Content-Length
    // gets a WRITTEN 400 (the old layer dropped the connection), and the
    // server closes after it.
    let mut s3 = TcpStream::connect(addr).unwrap();
    s3.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s3.write_all(
        b"POST /healthz HTTP/1.1\r\nHost: e2e\r\nContent-Length: 3\r\nContent-Length: 5\r\n\r\nabc",
    )
    .unwrap();
    let mut r3 = BufReader::new(s3);
    let (status, headers, body) = read_one_response(&mut r3);
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    assert_eq!(header(&headers, "connection"), Some("close"), "{headers:?}");

    let (status, _) = http(&addr.to_string(), "POST", "/shutdown", None);
    assert_eq!(status, 200);
    server_thread.join().expect("join").expect("run");
}

/// ISSUE 8 tentpole leg: saturating the bounded accept queue yields
/// fast 429s with `Retry-After` — never a hang — and queued connections
/// still serve once a worker frees up.
#[test]
fn bounded_accept_queue_sheds_429_and_never_hangs() {
    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0,
        persist: false,
        http_workers: 1,
        fit_workers: 1,
        queue_depth: 1,
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr");
    let server_thread = std::thread::spawn(move || server.run());

    // c1 occupies the single worker: one served request, then the
    // worker blocks reading the kept-alive socket for the next one.
    let c1 = TcpStream::connect(addr).expect("connect c1");
    c1.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut w1 = c1.try_clone().unwrap();
    let mut r1 = BufReader::new(c1);
    w1.write_all(&raw_request("GET", "/healthz", "", &[], false))
        .unwrap();
    let (status, _, _) = read_one_response(&mut r1);
    assert_eq!(status, 200);

    // c2 parks in the accept queue (depth 1).
    let c2 = TcpStream::connect(addr).expect("connect c2");
    c2.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // c3 finds the queue full: shed immediately with 429 + Retry-After.
    // The client writes nothing — the shed happens at admission.
    let t0 = Instant::now();
    let c3 = TcpStream::connect(addr).expect("connect c3");
    c3.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut r3 = BufReader::new(c3);
    let (status, headers, body) = read_one_response(&mut r3);
    assert_eq!(status, 429, "{}", String::from_utf8_lossy(&body));
    assert_eq!(header(&headers, "retry-after"), Some("1"), "{headers:?}");
    assert_eq!(header(&headers, "connection"), Some("close"), "{headers:?}");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shed must not wait for a worker"
    );

    // Freeing the worker (close c1) drains the queue: c2 now serves.
    drop(w1);
    drop(r1);
    let mut w2 = c2.try_clone().unwrap();
    let mut r2 = BufReader::new(c2);
    w2.write_all(&raw_request("GET", "/healthz", "", &[], true))
        .unwrap();
    let (status, _, _) = read_one_response(&mut r2);
    assert_eq!(status, 200);

    let (status, _) = http(&addr.to_string(), "POST", "/shutdown", None);
    assert_eq!(status, 200);
    server_thread.join().expect("join").expect("run");
}

/// ISSUE 8 tentpole leg: a kept-alive connection that goes idle past the
/// deadline is closed by the server (silently — nothing to answer).
#[test]
fn idle_keepalive_connection_closed_by_deadline() {
    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0,
        persist: false,
        http_workers: 1,
        fit_workers: 1,
        keepalive_idle: Duration::from_millis(300),
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr");
    let server_thread = std::thread::spawn(move || server.run());

    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer
        .write_all(&raw_request("GET", "/healthz", "", &[], false))
        .unwrap();
    let (status, headers, _) = read_one_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(header(&headers, "connection"), Some("keep-alive"), "{headers:?}");

    // Go idle: the server closes within the deadline (+ generous slack —
    // the client read timeout would turn a hang into an Err here).
    let mut rest = Vec::new();
    reader
        .read_to_end(&mut rest)
        .expect("server must close the idle connection, not leave it hanging");
    assert!(rest.is_empty(), "idle close sends no bytes");

    let (status, _) = http(&addr.to_string(), "POST", "/shutdown", None);
    assert_eq!(status, 200);
    server_thread.join().expect("join").expect("run");
}

/// ISSUE 9: every response carries an `X-Request-Id` — echoed verbatim
/// when the client supplies one, generated (`req-N`) when absent, and
/// present even on the written 400 for a malformed request — and that
/// 400 leaves an `http.malformed` event in the flight recorder, which
/// `GET /debug/log` serves live.
#[test]
fn request_ids_echo_and_debug_log_captures_malformed() {
    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0,
        persist: false,
        http_workers: 1,
        fit_workers: 1,
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr");
    let server_thread = std::thread::spawn(move || server.run());

    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // Client-supplied id: echoed verbatim (whitespace-trimmed).
    writer
        .write_all(
            b"GET /healthz HTTP/1.1\r\nHost: e2e\r\nX-Request-Id:  e2e-supplied-42 \r\n\
              Content-Length: 0\r\n\r\n",
        )
        .unwrap();
    let (status, headers, _) = read_one_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(
        header(&headers, "x-request-id"),
        Some("e2e-supplied-42"),
        "{headers:?}"
    );

    // No id supplied: the server mints one.
    writer
        .write_all(&raw_request("GET", "/healthz", "", &[], false))
        .unwrap();
    let (status, headers, _) = read_one_response(&mut reader);
    assert_eq!(status, 200);
    let generated = header(&headers, "x-request-id").expect("generated X-Request-Id");
    assert!(generated.starts_with("req-"), "{generated:?}");

    // Malformed request (conflicting duplicate Content-Length): the
    // written 400 still carries a (minted) request id.
    writer
        .write_all(
            b"GET /healthz HTTP/1.1\r\nHost: e2e\r\n\
              Content-Length: 1\r\nContent-Length: 2\r\n\r\n",
        )
        .unwrap();
    let (status, headers, _) = read_one_response(&mut reader);
    assert_eq!(status, 400);
    assert!(header(&headers, "x-request-id").is_some(), "{headers:?}");

    // The rejection went through the structured logger into the flight
    // recorder, which `GET /debug/log` serves as parsed entries.
    let (status, log) = http(&addr.to_string(), "GET", "/debug/log", None);
    assert_eq!(status, 200, "{log:?}");
    let entries = log.get("entries").and_then(Json::as_array).expect("entries");
    assert!(
        entries
            .iter()
            .any(|e| e.get("event").and_then(Json::as_str) == Some("http.malformed")),
        "no http.malformed event among {} /debug/log entries",
        entries.len()
    );

    let (status, _) = http(&addr.to_string(), "POST", "/shutdown", None);
    assert_eq!(status, 200);
    server_thread.join().expect("join").expect("run");
}

/// ISSUE 10 tentpole leg: the observe → refresh → assign lifecycle over
/// real TCP — ingest queues a refresh, assigns keep answering while the
/// off-thread publisher works, `GET /models/{id}` reports the bumped
/// version, and a registry reopened on the same data dir reloads the
/// refreshed version bit-exactly (the atomic versioned persist).
#[test]
fn observe_refresh_bumps_version_and_survives_reload() {
    let dir = std::env::temp_dir().join("fkmpp_serve_e2e_observe");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0,
        data_dir: dir.clone(),
        artifacts_dir: "/nonexistent".into(),
        http_workers: 2,
        fit_workers: 1,
        persist: true,
        observe_refresh_every: 32,
        ..ServeConfig::default()
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr").to_string();
    let reg = server.registry();
    let centers = gaussian_mixture(
        &SynthSpec {
            n: 4,
            d: 3,
            k_true: 2,
            ..Default::default()
        },
        5,
    );
    let meta = ModelMeta {
        id: reg.fresh_id(),
        version: 1,
        algorithm: "uniform".to_string(),
        k: 4,
        dim: 3,
        source: "test".to_string(),
        seed: 0,
        seeding_secs: 0.0,
        lloyd_iters: 0,
        cost: 0.0,
    };
    let model_id = meta.id.clone();
    reg.insert(meta, centers).expect("insert model");
    let server_thread = std::thread::spawn(move || server.run());

    // Version 1 before any ingest.
    let (status, model) = http(&addr, "GET", &format!("/models/{model_id}"), None);
    assert_eq!(status, 200, "{model:?}");
    assert_eq!(model.get("version").and_then(Json::as_usize), Some(1));

    // One 40-point batch crosses the 32-point cadence: the response
    // reports the queued version immediately.
    let batch = gaussian_mixture(
        &SynthSpec {
            n: 40,
            d: 3,
            k_true: 2,
            ..Default::default()
        },
        9,
    );
    let observe_body = Json::obj(vec![("points", json::points_to_json(&batch))]).emit();
    let (status, obs) = http(
        &addr,
        "POST",
        &format!("/models/{model_id}/observe"),
        Some(&observe_body),
    );
    assert_eq!(status, 200, "{obs:?}");
    assert_eq!(obs.get("ingested").and_then(Json::as_usize), Some(40));
    assert_eq!(obs.get("total_observed").and_then(Json::as_usize), Some(40));
    assert_eq!(obs.get("queued_version").and_then(Json::as_usize), Some(2));

    // Assigns keep answering while the refresh publishes off-thread, and
    // the served version eventually bumps to the queued one.
    let assign_body = Json::obj(vec![("points", json::points_to_json(&batch))]).emit();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, assigned) = http(
            &addr,
            "POST",
            &format!("/models/{model_id}/assign"),
            Some(&assign_body),
        );
        assert_eq!(status, 200, "assign during refresh window: {assigned:?}");
        let (status, doc) = http(&addr, "GET", &format!("/models/{model_id}"), None);
        assert_eq!(status, 200, "{doc:?}");
        match doc.get("version").and_then(Json::as_usize) {
            Some(v) if v >= 2 => {
                assert_eq!(v, 2, "exactly one refresh was queued");
                break;
            }
            _ => {
                assert!(Instant::now() < deadline, "version never bumped past 1");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }

    // Capture the refreshed centers as served, then shut down.
    let (status, doc) = http(&addr, "GET", &format!("/models/{model_id}"), None);
    assert_eq!(status, 200, "{doc:?}");
    let refreshed =
        json::points_from_json(doc.get("centers").expect("centers")).expect("parse centers");
    let (status, _) = http(&addr, "POST", "/shutdown", None);
    assert_eq!(status, 200);
    server_thread.join().expect("join").expect("run");

    // A fresh registry over the same data dir reloads the refreshed
    // version with the same bits (a server restart keeps serving v2).
    let reloaded = ModelRegistry::new(Some(dir)).expect("reload registry");
    let model = reloaded.get(&model_id).expect("model persisted");
    assert_eq!(model.meta.version, 2);
    assert_eq!(model.centers, refreshed);
}
