//! End-to-end serving-layer test: boots the `fkmpp serve` subsystem on
//! an ephemeral port, drives the full `POST /fit` → `GET /jobs/{id}` →
//! `POST /models/{id}/assign` lifecycle over real TCP with a raw HTTP/1.1
//! client, and asserts that served labels match a direct
//! `kernels::assign::assign_argmin` call **exactly** (the ISSUE 2
//! acceptance criterion).
//!
//! Exactness holds because the JSON layer's shortest-round-trip float
//! emitter makes `f32 → f64 → text → f64 → f32` bit-exact in both
//! directions, so the server computes on the same bits we do.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::kernels::assign::assign_argmin;
use fastkmeanspp::server::json::{self, Json};
use fastkmeanspp::server::registry::ModelRegistry;
use fastkmeanspp::server::{ServeConfig, Server};

/// Minimal blocking HTTP client: one request, `Connection: close`, parse
/// status + JSON body.
fn http(addr: &str, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let body = body.unwrap_or("");
    let request = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(request.as_bytes()).expect("write request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {raw:?}"))
        .parse()
        .expect("status code");
    let split = raw.find("\r\n\r\n").expect("header/body split");
    let body = &raw[split + 4..];
    let parsed = if body.is_empty() {
        Json::Null
    } else {
        json::parse(body).unwrap_or_else(|e| panic!("bad JSON body {body:?}: {e:#}"))
    };
    (status, parsed)
}

/// Same raw client, but returns headers + body text unparsed (the
/// Prometheus exposition is not JSON).
fn http_text(addr: &str, path: &str) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let request = format!(
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(request.as_bytes()).expect("write request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("read response");
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("no status line in {raw:?}"))
        .parse()
        .expect("status code");
    let split = raw.find("\r\n\r\n").expect("header/body split");
    (status, raw[..split].to_string(), raw[split + 4..].to_string())
}

/// Prometheus metric-name grammar: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn valid_prometheus_name(name: &str) -> bool {
    let mut chars = name.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':')
        && chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

#[test]
fn serve_fit_job_assign_roundtrip() {
    let dir = std::env::temp_dir().join("fkmpp_serve_e2e");
    let _ = std::fs::remove_dir_all(&dir);

    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0, // ephemeral
        data_dir: dir.clone(),
        artifacts_dir: "/nonexistent".into(),
        http_workers: 2,
        fit_workers: 1,
        persist: true,
    };
    let server = Server::bind(&cfg).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr").to_string();
    let server_thread = std::thread::spawn(move || server.run());

    // Liveness.
    let (status, health) = http(&addr, "GET", "/healthz", None);
    assert_eq!(status, 200, "{health:?}");
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));

    // Fit: inline points through the paper's rejection sampler + Lloyd.
    let train = gaussian_mixture(
        &SynthSpec {
            n: 400,
            d: 6,
            k_true: 5,
            ..Default::default()
        },
        11,
    );
    let fit_body = Json::obj(vec![
        ("points", json::points_to_json(&train)),
        ("algo", Json::str("rejection")),
        ("k", Json::num(5.0)),
        ("seed", Json::num(7.0)),
        ("lloyd", Json::num(2.0)),
    ])
    .emit();
    let (status, fit) = http(&addr, "POST", "/fit", Some(&fit_body));
    assert_eq!(status, 202, "{fit:?}");
    let job_id = fit
        .get("job_id")
        .and_then(Json::as_str)
        .expect("job_id")
        .to_string();

    // The job id comes back immediately; poll it to completion.
    let deadline = Instant::now() + Duration::from_secs(120);
    let model_id = loop {
        let (status, job) = http(&addr, "GET", &format!("/jobs/{job_id}"), None);
        assert_eq!(status, 200, "{job:?}");
        match job.get("state").and_then(Json::as_str) {
            Some("done") => {
                assert!(job.get("secs").and_then(Json::as_f64).unwrap() >= 0.0);
                break job
                    .get("model_id")
                    .and_then(Json::as_str)
                    .expect("model_id")
                    .to_string();
            }
            Some("failed") => panic!("fit failed: {job:?}"),
            _ => {
                assert!(Instant::now() < deadline, "fit did not finish in time");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    };

    // The model is listed and fully retrievable.
    let (status, models) = http(&addr, "GET", "/models", None);
    assert_eq!(status, 200);
    assert_eq!(models.get("count").and_then(Json::as_usize), Some(1));
    let (status, model) = http(&addr, "GET", &format!("/models/{model_id}"), None);
    assert_eq!(status, 200, "{model:?}");
    assert_eq!(model.get("algorithm").and_then(Json::as_str), Some("rejection"));
    let centers =
        json::points_from_json(model.get("centers").expect("centers")).expect("parse centers");
    assert_eq!(centers.len(), 5);
    assert_eq!(centers.dim(), 6);

    // Batched assignment through the server...
    let queries = gaussian_mixture(
        &SynthSpec {
            n: 120,
            d: 6,
            k_true: 5,
            ..Default::default()
        },
        23,
    );
    let assign_body = Json::obj(vec![("points", json::points_to_json(&queries))]).emit();
    let (status, assigned) = http(
        &addr,
        "POST",
        &format!("/models/{model_id}/assign"),
        Some(&assign_body),
    );
    assert_eq!(status, 200, "{assigned:?}");
    let labels: Vec<u32> = assigned
        .get("labels")
        .and_then(Json::as_array)
        .expect("labels")
        .iter()
        .map(|v| v.as_f64().expect("numeric label") as u32)
        .collect();
    let served_d2: Vec<f32> = assigned
        .get("d2")
        .and_then(Json::as_array)
        .expect("d2")
        .iter()
        .map(|v| v.as_f64().expect("numeric d2") as f32)
        .collect();

    // ...must exactly match the kernel engine on the same bits.
    let (want_labels, want_d2) = assign_argmin(&queries, &centers);
    assert_eq!(
        labels, want_labels,
        "served labels must match kernels::assign::assign_argmin exactly"
    );
    assert_eq!(served_d2, want_d2, "served distances must match the kernel");

    // Kernels-v2 satellite: the model's center-norm cache is computed
    // once at registration; repeated identical assign requests must
    // serve BYTE-identical label/distance vectors (no per-request
    // recomputation drift).
    let first_emit = (
        assigned.get("labels").expect("labels").emit(),
        assigned.get("d2").expect("d2").emit(),
    );
    for rep in 0..3 {
        let (status, again) = http(
            &addr,
            "POST",
            &format!("/models/{model_id}/assign"),
            Some(&assign_body),
        );
        assert_eq!(status, 200, "repeat {rep}: {again:?}");
        let emit = (
            again.get("labels").expect("labels").emit(),
            again.get("d2").expect("d2").emit(),
        );
        assert_eq!(emit, first_emit, "repeat {rep}: response must be byte-identical");
    }

    // Sharded-seeding satellite: a `kmeans_par` fit runs through the
    // shard engine and its round counters/timings surface at /metrics.
    let par_fit_body = Json::obj(vec![
        ("points", json::points_to_json(&train)),
        ("algorithm", Json::str("kmeans_par")),
        ("k", Json::num(5.0)),
        ("seed", Json::num(13.0)),
        ("shards", Json::num(2.0)),
        ("rounds", Json::num(3.0)),
        ("oversample", Json::num(2.0)),
    ])
    .emit();
    let (status, par_fit) = http(&addr, "POST", "/fit", Some(&par_fit_body));
    assert_eq!(status, 202, "{par_fit:?}");
    let par_job = par_fit
        .get("job_id")
        .and_then(Json::as_str)
        .expect("job_id")
        .to_string();
    let par_deadline = Instant::now() + Duration::from_secs(120);
    let par_model_id = loop {
        let (status, job) = http(&addr, "GET", &format!("/jobs/{par_job}"), None);
        assert_eq!(status, 200, "{job:?}");
        match job.get("state").and_then(Json::as_str) {
            Some("done") => {
                break job
                    .get("model_id")
                    .and_then(Json::as_str)
                    .expect("model_id")
                    .to_string()
            }
            Some("failed") => panic!("kmeans_par fit failed: {job:?}"),
            _ => {
                assert!(Instant::now() < par_deadline, "kmeans_par fit did not finish");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    };
    let (status, par_model) = http(&addr, "GET", &format!("/models/{par_model_id}"), None);
    assert_eq!(status, 200, "{par_model:?}");
    assert_eq!(
        par_model.get("algorithm").and_then(Json::as_str),
        Some("kmeans-par")
    );
    let (status, shard_metrics) = http(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let shard_rounds = shard_metrics
        .get("counters")
        .and_then(|c| c.get("shard.rounds"))
        .and_then(Json::as_usize)
        .unwrap_or(0);
    // The fit asked for 3 rounds (early-exit only if candidates cover
    // every point exactly, impossible on a Gaussian mixture with k=5).
    assert!(shard_rounds >= 3, "{shard_metrics:?}");
    assert!(
        shard_metrics
            .get("counters")
            .and_then(|c| c.get("shard.runs"))
            .and_then(Json::as_usize)
            .unwrap_or(0)
            >= 1,
        "{shard_metrics:?}"
    );
    assert!(
        shard_metrics
            .get("timings")
            .and_then(|t| t.get("shard.round_secs"))
            .and_then(|s| s.get("mean"))
            .is_some(),
        "{shard_metrics:?}"
    );

    // LSH-oracle satellite: a rejection fit with the oracle selected per
    // request runs end-to-end, and the oracle counters surface at
    // /metrics (the acceptance-loop flush to the process-wide sink).
    let lsh_fit_body = Json::obj(vec![
        ("points", json::points_to_json(&train)),
        ("algo", Json::str("rejection")),
        ("oracle", Json::str("lsh")),
        ("k", Json::num(5.0)),
        ("seed", Json::num(17.0)),
    ])
    .emit();
    let (status, lsh_fit) = http(&addr, "POST", "/fit", Some(&lsh_fit_body));
    assert_eq!(status, 202, "{lsh_fit:?}");
    let lsh_job = lsh_fit
        .get("job_id")
        .and_then(Json::as_str)
        .expect("job_id")
        .to_string();
    let lsh_deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, job) = http(&addr, "GET", &format!("/jobs/{lsh_job}"), None);
        assert_eq!(status, 200, "{job:?}");
        match job.get("state").and_then(Json::as_str) {
            Some("done") => break,
            Some("failed") => panic!("lsh-oracle fit failed: {job:?}"),
            _ => {
                assert!(Instant::now() < lsh_deadline, "lsh-oracle fit did not finish");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
    let (status, oracle_metrics) = http(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    let counters = oracle_metrics.get("counters").expect("counters");
    for name in ["oracle.probes", "oracle.accepts", "oracle.rejects", "oracle.proposals"] {
        assert!(
            counters.get(name).and_then(Json::as_f64).is_some(),
            "{name} missing from {oracle_metrics:?}"
        );
    }
    // Two rejection fits ran (5 centers each): accepts reached at least 10.
    assert!(
        counters
            .get("oracle.accepts")
            .and_then(Json::as_usize)
            .unwrap_or(0)
            >= 10,
        "{oracle_metrics:?}"
    );
    assert!(
        oracle_metrics
            .get("timings")
            .and_then(|t| t.get("oracle.probe_secs"))
            .and_then(|s| s.get("mean"))
            .is_some(),
        "{oracle_metrics:?}"
    );
    // An unknown oracle name is a client error, not a queued-then-failed job.
    let (status, bad_oracle) = http(
        &addr,
        "POST",
        "/fit",
        Some(r#"{"points": [[1,2],[3,4]], "k": 1, "algo": "rejection", "oracle": "bogus"}"#),
    );
    assert_eq!(status, 400, "{bad_oracle:?}");

    // Error paths stay clean under load.
    let (status, _) = http(&addr, "GET", "/jobs/job-999", None);
    assert_eq!(status, 404);
    let (status, _) = http(&addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _) = http(&addr, "POST", "/fit", Some("not json"));
    assert_eq!(status, 400);

    // Metrics saw the traffic (three models now: rejection + kmeans_par
    // + the lsh-oracle rejection fit).
    let (status, metrics) = http(&addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert_eq!(metrics.get("models").and_then(Json::as_usize), Some(3));
    assert!(
        metrics
            .get("counters")
            .and_then(|c| c.get("http.requests"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
            >= 5.0,
        "{metrics:?}"
    );
    // 120 query points x (1 + 3 repeated) assign calls.
    assert!(
        metrics
            .get("counters")
            .and_then(|c| c.get("assign.points"))
            .and_then(Json::as_usize)
            == Some(480),
        "{metrics:?}"
    );
    // Observability satellite: request latency is a log-bucketed
    // histogram now — /metrics reports its p50/p99 (JSON side).
    let http_latency = metrics
        .get("timings")
        .and_then(|t| t.get("http.latency_secs"))
        .unwrap_or_else(|| panic!("no http.latency_secs in {metrics:?}"));
    for q in ["p50", "p99", "count", "mean"] {
        assert!(
            http_latency.get(q).and_then(Json::as_f64).is_some(),
            "{q} missing from http.latency_secs: {metrics:?}"
        );
    }

    // Prometheus exposition satellite: the same metrics as text/plain
    // v0.0.4, parsed line-by-line — every metric name obeys the grammar,
    // every histogram's cumulative buckets are monotone and agree with
    // its `_count` series.
    let (status, headers, prom) = http_text(&addr, "/metrics?format=prometheus");
    assert_eq!(status, 200, "{prom}");
    assert!(
        headers.to_ascii_lowercase().contains("text/plain; version=0.0.4"),
        "missing exposition content type in {headers:?}"
    );
    for needle in [
        "# TYPE fkmpp_http_latency_secs histogram",
        "fkmpp_http_latency_secs_bucket{le=\"+Inf\"}",
        "fkmpp_shard_rounds_total",
        "fkmpp_oracle_probe_secs_bucket",
    ] {
        assert!(prom.contains(needle), "{needle:?} missing from:\n{prom}");
    }
    let mut buckets: Vec<(String, String, u64)> = Vec::new(); // (metric, le, cum)
    let mut scalars: Vec<(String, f64)> = Vec::new();
    for line in prom.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (series, value) = line
            .rsplit_once(' ')
            .unwrap_or_else(|| panic!("bad exposition line {line:?}"));
        if let Some((metric, rest)) = series.split_once("_bucket{le=\"") {
            let le = rest
                .strip_suffix("\"}")
                .unwrap_or_else(|| panic!("bad bucket label in {line:?}"));
            assert!(valid_prometheus_name(metric), "bad name in {line:?}");
            let cum: u64 = value.parse().unwrap_or_else(|_| panic!("bad count {line:?}"));
            buckets.push((metric.to_string(), le.to_string(), cum));
        } else {
            assert!(valid_prometheus_name(series), "bad name in {line:?}");
            let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value {line:?}"));
            scalars.push((series.to_string(), v));
        }
    }
    assert!(!buckets.is_empty(), "no histogram series in:\n{prom}");
    // Per-histogram: cumulative counts nondecreasing, le edges strictly
    // increasing, and the +Inf bucket equals the `_count` scalar.
    let metric_names: Vec<String> = {
        let mut v: Vec<String> = buckets.iter().map(|(m, _, _)| m.clone()).collect();
        v.dedup();
        v
    };
    for metric in &metric_names {
        let series: Vec<&(String, String, u64)> =
            buckets.iter().filter(|(m, _, _)| m == metric).collect();
        let mut last_cum = 0u64;
        let mut last_le = f64::NEG_INFINITY;
        let mut inf_cum = None;
        for (_, le, cum) in &series {
            assert!(*cum >= last_cum, "{metric}: non-monotone buckets:\n{prom}");
            last_cum = *cum;
            if le == "+Inf" {
                inf_cum = Some(*cum);
            } else {
                let edge: f64 = le.parse().unwrap_or_else(|_| panic!("bad le {le:?}"));
                assert!(edge > last_le, "{metric}: le edges not increasing");
                last_le = edge;
            }
        }
        let inf_cum = inf_cum.unwrap_or_else(|| panic!("{metric}: no +Inf bucket"));
        let count = scalars
            .iter()
            .find(|(n, _)| n == &format!("{metric}_count"))
            .unwrap_or_else(|| panic!("{metric}: no _count series"))
            .1;
        assert_eq!(inf_cum as f64, count, "{metric}: +Inf bucket != _count");
    }

    // Graceful shutdown drains the pools and run() returns Ok.
    let (status, _) = http(&addr, "POST", "/shutdown", None);
    assert_eq!(status, 200);
    server_thread
        .join()
        .expect("server thread")
        .expect("server run");

    // Persistence: a fresh registry over the same data dir reloads the
    // model bit-exactly (what a server restart would see).
    let reloaded = ModelRegistry::new(Some(dir)).expect("reload registry");
    let model = reloaded.get(&model_id).expect("model persisted");
    assert_eq!(model.centers, centers);
    assert_eq!(model.meta.k, 5);
}
