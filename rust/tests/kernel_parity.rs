//! Property tests for the **v1** parallel distance-kernel engine: on
//! random instances, every kernel must agree with a naive serial
//! reference — bit-exactly where the arithmetic order is identical,
//! within tree-sum rounding otherwise — across thread counts
//! (`FKMPP_THREADS` in {1, 4}).
//!
//! The thread-count sweep lives in ONE test function on purpose: the
//! kernels read `FKMPP_THREADS` per call, so a single test owning the
//! env var avoids cross-test interleaving ever pinning a surprising
//! thread count on an assertion that depends on it (no kernel result
//! may depend on the thread count — that is exactly what this file
//! checks).
//!
//! Since the kernels-v2 rework the public entry points dispatch between
//! the v1 loops and the blocked norm-trick loops
//! (`FKMPP_KERNEL`, `rust/src/kernels/tune.rs`). This file pins
//! `FKMPP_KERNEL=naive` — its references ARE the v1 semantics, and the
//! bit-exact assertions below would be meaningless against the other
//! formulation's rounding. The v2 kernels get the same treatment in
//! `rust/tests/kernel_parity_v2.rs`.

use fastkmeanspp::data::matrix::{d2, PointSet};
use fastkmeanspp::kernels::{assign, d2 as d2_kernel, reduce};
use fastkmeanspp::rng::Pcg64;

fn random_points(n: usize, d: usize, rng: &mut Pcg64) -> PointSet {
    let data: Vec<f32> = (0..n * d)
        .map(|_| (rng.next_gaussian() * 10.0) as f32)
        .collect();
    PointSet::from_flat(n, d, data)
}

/// Naive references, written with the same scalar `d2` so bit-exact
/// comparison is legitimate.
fn naive_update_min(ps: &PointSet, center: &[f32], cur: &mut [f32]) {
    for i in 0..ps.len() {
        let dd = d2(ps.row(i), center);
        if dd < cur[i] {
            cur[i] = dd;
        }
    }
}

fn naive_assign(ps: &PointSet, centers: &PointSet) -> (Vec<u32>, Vec<f32>) {
    let mut idx = vec![0u32; ps.len()];
    let mut mind2 = vec![f32::INFINITY; ps.len()];
    for i in 0..ps.len() {
        for j in 0..centers.len() {
            let dd = d2(ps.row(i), centers.row(j));
            if dd < mind2[i] {
                mind2[i] = dd;
                idx[i] = j as u32;
            }
        }
    }
    (idx, mind2)
}

#[test]
fn kernels_match_serial_reference_across_thread_counts() {
    // This binary has exactly one test, so it owns both env vars.
    std::env::set_var("FKMPP_KERNEL", "naive");
    for &threads in &[1usize, 4] {
        std::env::set_var("FKMPP_THREADS", threads.to_string());
        let mut rng = Pcg64::seed_from(0xBEEF ^ threads as u64);
        for case in 0..8 {
            // Random shapes, including degenerate ones (n=1, d=1, k=1)
            // and shapes straddling the kernels' inline/parallel cutoffs.
            let n = 1 + rng.index(9_000);
            let d = 1 + rng.index(40);
            let k = 1 + rng.index(70).min(n - 1);
            let ps = random_points(n, d, &mut rng);
            let centers = ps.gather(&(0..k).map(|_| rng.index(n)).collect::<Vec<_>>());
            let ctx = format!("threads={threads} case={case} n={n} d={d} k={k}");

            // d2_update_min: seeded with a random prior distance array so
            // both the "update" and "keep" branches are exercised.
            let prior: Vec<f32> = (0..n).map(|_| rng.next_f32() * 100.0).collect();
            let mut got = prior.clone();
            let mut want = prior.clone();
            d2_kernel::d2_update_min(&ps, centers.row(0), &mut got);
            naive_update_min(&ps, centers.row(0), &mut want);
            assert_eq!(got, want, "d2_update_min {ctx}");

            // assign_argmin (tiled + parallel) vs the naive double loop.
            let (gi, gd) = assign::assign_argmin(&ps, &centers);
            let (wi, wd) = naive_assign(&ps, &centers);
            assert_eq!(gi, wi, "assign idx {ctx}");
            assert_eq!(gd, wd, "assign d2 {ctx}");

            // cost: parallel tree sum vs serial f64 fold over the naive
            // assignment (different summation order -> relative epsilon).
            let want_cost: f64 = wd.iter().map(|&v| v as f64).sum();
            let got_cost = reduce::cost(&ps, &centers);
            assert!(
                (got_cost - want_cost).abs() <= 1e-9 * want_cost.max(1.0),
                "cost {ctx}: {got_cost} vs {want_cost}"
            );

            // sum_f32 and block_sums over the distance array.
            let want_sum: f64 = wd.iter().map(|&v| v as f64).sum();
            let got_sum = reduce::sum_f32(&wd);
            assert!(
                (got_sum - want_sum).abs() <= 1e-9 * want_sum.max(1.0),
                "sum_f32 {ctx}"
            );
            let block = 1 + rng.index(n.max(2));
            let bs = reduce::block_sums(&wd, block);
            assert_eq!(bs.len(), n.div_ceil(block), "block count {ctx}");
            let total: f64 = bs.iter().sum();
            assert!(
                (total - want_sum).abs() <= 1e-9 * want_sum.max(1.0),
                "block_sums total {ctx}"
            );

            // max_d2_to: exact (same per-element d2, max is order-free).
            let pivot = ps.row(0).to_vec();
            let want_max = (0..n).map(|i| d2(ps.row(i), &pivot)).fold(0.0f32, f32::max);
            assert_eq!(reduce::max_d2_to(&ps, &pivot), want_max, "max_d2 {ctx}");
        }
    }

    // End-to-end, same env-var ownership: the same seed must pick the
    // same centers at 1 and 4 threads — the kernels may not let
    // parallelism leak into results.
    use fastkmeanspp::seeding::kmeanspp::kmeanspp;
    let mut gen = Pcg64::seed_from(77);
    let ps = random_points(4_000, 12, &mut gen);
    let mut picked = Vec::new();
    for &threads in &[1usize, 4] {
        std::env::set_var("FKMPP_THREADS", threads.to_string());
        let mut rng = Pcg64::seed_from(123);
        picked.push(kmeanspp(&ps, 25, &mut rng).indices);
    }
    std::env::remove_var("FKMPP_THREADS");
    std::env::remove_var("FKMPP_KERNEL");
    assert_eq!(picked[0], picked[1], "seeding must be thread-count invariant");
}
