//! Full-pipeline integration: dataset registry -> Appendix-F quantization
//! -> seeding -> Lloyd refinement -> tables, exactly the path the CLI and
//! benches drive, on the smoke profile.

use fastkmeanspp::coordinator::config::ExperimentConfig;
use fastkmeanspp::coordinator::{run_grid, tables};
use fastkmeanspp::data::registry::{DatasetId, Profile};
use fastkmeanspp::seeding::SeedingAlgorithm;

fn smoke_cfg() -> ExperimentConfig {
    ExperimentConfig {
        datasets: vec![DatasetId::KddSim],
        profile: Profile::Smoke,
        algorithms: vec![
            SeedingAlgorithm::FastKMeansPP,
            SeedingAlgorithm::Rejection,
            SeedingAlgorithm::KMeansPP,
            SeedingAlgorithm::Afkmc2,
            SeedingAlgorithm::Uniform,
        ],
        ks: vec![20, 60],
        reps: 2,
        seed: 99,
        data_dir: std::env::temp_dir().join("fkmpp_e2e_test"),
        artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        ..Default::default()
    }
}

#[test]
fn grid_and_all_table_emitters() {
    let cfg = smoke_cfg();
    let res = run_grid(&cfg, |_| {}).unwrap();
    assert_eq!(res.cells.len(), 10);

    let t1 = tables::runtime_table(&res, DatasetId::KddSim, &cfg.ks);
    assert!(t1.contains("FASTK-MEANS++ | 1.00x"), "{t1}");
    assert!(t1.contains("K-MEANS++"));

    let t4 = tables::cost_table(&res, DatasetId::KddSim, &cfg.ks);
    assert!(t4.contains("UNIFORMSAMPLING"));
    // No dashes: every cell filled.
    assert!(!t4.contains('—'), "{t4}");

    let t8 = tables::variance_table(&res, DatasetId::KddSim, &cfg.ks);
    assert!(t8.contains("Table 8"));

    let diag = tables::rejection_diagnostics(&res, DatasetId::KddSim, &cfg.ks);
    assert!(diag.contains("REJECTIONSAMPLING"), "{diag}");
}

#[test]
fn lloyd_refinement_through_grid() {
    let mut cfg = smoke_cfg();
    cfg.algorithms = vec![SeedingAlgorithm::Rejection];
    cfg.ks = vec![30];
    cfg.lloyd_iters = 4;
    let res = run_grid(&cfg, |_| {}).unwrap();
    let cell = res
        .get(DatasetId::KddSim, SeedingAlgorithm::Rejection, 30)
        .unwrap();
    assert!(cell.lloyd_cost.count() > 0);
    assert!(
        cell.lloyd_cost.mean() <= cell.cost.mean(),
        "lloyd {:.4e} > seed {:.4e}",
        cell.lloyd_cost.mean(),
        cell.cost.mean()
    );
}

#[test]
fn cli_table_command_smoke() {
    let argv: Vec<String> = [
        "table",
        "--which",
        "4",
        "--profile",
        "smoke",
        "--ks",
        "15,40",
        "--reps",
        "1",
        "--data-dir",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([std::env::temp_dir()
        .join("fkmpp_e2e_cli")
        .to_string_lossy()
        .into_owned()])
    .collect();
    let out = fastkmeanspp::cli::run(&argv).unwrap();
    assert!(out.contains("Table 4"), "{out}");
    assert!(out.contains("K-MEANS++"));
}

#[test]
fn deterministic_given_seed() {
    let cfg = {
        let mut c = smoke_cfg();
        c.algorithms = vec![SeedingAlgorithm::FastKMeansPP];
        c.ks = vec![25];
        c.reps = 1;
        c
    };
    let a = run_grid(&cfg, |_| {}).unwrap();
    let b = run_grid(&cfg, |_| {}).unwrap();
    let ka = a
        .get(DatasetId::KddSim, SeedingAlgorithm::FastKMeansPP, 25)
        .unwrap();
    let kb = b
        .get(DatasetId::KddSim, SeedingAlgorithm::FastKMeansPP, 25)
        .unwrap();
    assert_eq!(ka.cost.mean(), kb.cost.mean());
}
