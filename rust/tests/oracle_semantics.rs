//! Oracle-semantics integration tests: the early-exit indicator
//! (`dist_below`) must agree with the full minimum (`query`) on the same
//! candidate set — this equivalence is what makes the rejection
//! sampler's indicator-form acceptance test *exactly* the Algorithm-4
//! probability — plus prefix-exactness and cross-oracle agreement.

use fastkmeanspp::data::matrix::PointSet;
use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::lsh::multiscale::{auto_bucket_width_for_k, LshParams, MonotoneLsh, PREFIX_CAP};
use fastkmeanspp::lsh::{ExactNn, NnOracle};
use fastkmeanspp::rng::Pcg64;

fn dataset(n: usize, d: usize, seed: u64) -> PointSet {
    gaussian_mixture(
        &SynthSpec {
            n,
            d,
            k_true: 20,
            center_spread: 15.0,
            ..Default::default()
        },
        seed,
    )
}

#[test]
fn dist_below_matches_query_practical_mode() {
    // Property: for any threshold, dist_below == (query().dist < t).
    let ps = dataset(800, 12, 1);
    let mut rng = Pcg64::seed_from(2);
    let params = LshParams {
        bucket_width: auto_bucket_width_for_k(&ps, 200, 15, &mut rng),
        ..Default::default()
    };
    let mut lsh = MonotoneLsh::practical(12, &params, &mut rng);
    for i in 0..400u32 {
        lsh.insert(&ps, i);
    }
    let mut checked = 0;
    for q in 400..800 {
        let (_, dist) = lsh.query(&ps, ps.row(q)).unwrap();
        for mult in [0.5f32, 0.999, 1.001, 2.0] {
            let t = dist * mult;
            let got = lsh.dist_below(&ps, ps.row(q), t);
            assert_eq!(
                got,
                dist < t,
                "q={q} t={t} dist={dist} (mult {mult})"
            );
            checked += 1;
        }
    }
    assert!(checked > 1000);
}

#[test]
fn dist_below_matches_query_exact_oracle() {
    let ps = dataset(300, 8, 3);
    let mut nn = ExactNn::default();
    for i in 0..150u32 {
        nn.insert(&ps, i);
    }
    for q in 150..300 {
        let (_, dist) = nn.query(&ps, ps.row(q)).unwrap();
        assert!(nn.dist_below(&ps, ps.row(q), dist * 1.001));
        assert!(!nn.dist_below(&ps, ps.row(q), dist * 0.999));
    }
}

#[test]
fn lsh_exact_while_under_prefix_cap() {
    // While at most PREFIX_CAP points are inserted, MonotoneLsh must be
    // an EXACT nearest-neighbor oracle (the prefix scan covers all).
    let ps = dataset(600, 10, 5);
    let mut rng = Pcg64::seed_from(6);
    let params = LshParams {
        bucket_width: auto_bucket_width_for_k(&ps, 100, 15, &mut rng),
        ..Default::default()
    };
    let mut lsh = MonotoneLsh::practical(10, &params, &mut rng);
    let mut exact = ExactNn::default();
    assert!(PREFIX_CAP >= 100);
    for i in 0..100u32 {
        lsh.insert(&ps, i);
        exact.insert(&ps, i);
    }
    for q in 100..600 {
        let (_, dl) = lsh.query(&ps, ps.row(q)).unwrap();
        let (_, de) = exact.query(&ps, ps.row(q)).unwrap();
        assert!(
            (dl - de).abs() < 1e-5,
            "q={q}: lsh {dl} != exact {de} under the prefix cap"
        );
    }
}

#[test]
fn monotone_past_prefix_cap() {
    // Beyond the cap the oracle goes approximate but must stay monotone.
    let ps = dataset(1500, 10, 7);
    let mut rng = Pcg64::seed_from(8);
    let params = LshParams {
        bucket_width: auto_bucket_width_for_k(&ps, 400, 15, &mut rng),
        ..Default::default()
    };
    let mut lsh = MonotoneLsh::practical(10, &params, &mut rng);
    let queries: Vec<usize> = vec![1400, 1450, 1499];
    let mut last = vec![f32::INFINITY; queries.len()];
    for i in 0..400u32 {
        lsh.insert(&ps, i);
        for (slot, &q) in queries.iter().enumerate() {
            let (_, d) = lsh.query(&ps, ps.row(q)).unwrap();
            assert!(
                d <= last[slot] + 1e-5,
                "q={q} after insert {i}: {d} > {}",
                last[slot]
            );
            last[slot] = d;
        }
    }
}

#[test]
fn rejection_same_seed_same_centers_across_oracle_cost() {
    // The indicator-form accept test must be deterministic in the rng
    // seed (regression guard for the u-draw ordering).
    use fastkmeanspp::seeding::rejection::{rejection_sampling, RejectionConfig};
    let ps = dataset(2000, 16, 9);
    let cfg = RejectionConfig::default();
    let mut a = Pcg64::seed_from(11);
    let mut b = Pcg64::seed_from(11);
    let sa = rejection_sampling(&ps, 40, &cfg, &mut a);
    let sb = rejection_sampling(&ps, 40, &cfg, &mut b);
    assert_eq!(sa.indices, sb.indices);
    assert_eq!(sa.stats.proposals, sb.stats.proposals);
}

#[test]
fn rejection_distribution_unchanged_by_indicator_form() {
    // With the EXACT oracle and c=1 the accepted second-center marginal
    // must match the analytic D^2 distribution — the indicator-form
    // evaluation must not shift it (this is the Lemma 5.2 check).
    use fastkmeanspp::seeding::rejection::{rejection_sampling, OracleKind, RejectionConfig};
    let rows = vec![
        vec![0.0f32, 0.0],
        vec![2.0, 0.0],
        vec![0.0, 3.0],
        vec![8.0, 8.0],
    ];
    let ps = PointSet::from_rows(&rows);
    let cfg = RejectionConfig {
        c: 1.0,
        oracle: OracleKind::Exact,
        ..Default::default()
    };
    let trials = 40_000;
    let mut first = vec![0.0f64; 4];
    let mut second = vec![0.0f64; 4];
    for seed in 0..trials {
        let mut rng = Pcg64::seed_from(seed);
        let s = rejection_sampling(&ps, 2, &cfg, &mut rng);
        first[s.indices[0]] += 1.0;
        second[s.indices[1]] += 1.0;
    }
    let mut want = vec![0.0f64; 4];
    for f in 0..4 {
        let d2s: Vec<f64> = (0..4).map(|j| ps.d2_rows(j, f) as f64).collect();
        let sum: f64 = d2s.iter().sum();
        for j in 0..4 {
            want[j] += (first[f] / trials as f64) * d2s[j] / sum;
        }
    }
    for j in 0..4 {
        let got = second[j] / trials as f64;
        assert!(
            (got - want[j]).abs() < 0.012,
            "j={j} got={got} want={}",
            want[j]
        );
    }
}
