//! Oracle-semantics integration tests, two tiers:
//!
//! 1. the early-exit indicator (`dist_below`) must agree with the full
//!    minimum (`query`) on the same candidate set — this equivalence is
//!    what makes the rejection sampler's indicator-form acceptance test
//!    *exactly* the Algorithm-4 probability — plus prefix-exactness and
//!    cross-oracle agreement;
//! 2. the **adversarial oracle suite**: `MonotoneLsh` (Practical and
//!    Rigorous modes) against `ExactNn` on pathological inputs —
//!    duplicate points, coincident centers, zero vectors, d ∈ {1, 8,
//!    127} — asserting the monotone contract (the reported distance
//!    never increases as centers open) and soundness (the oracle never
//!    reports a distance below the true NN distance: every candidate is
//!    a real inserted point, so any mode's answer upper-bounds the
//!    truth; within the exact insertion prefix it *equals* it).

use fastkmeanspp::data::matrix::PointSet;
use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::kernels::norms::squared_norms;
use fastkmeanspp::lsh::multiscale::{
    auto_bucket_width_for_k, LshMode, LshParams, MonotoneLsh, PREFIX_CAP,
};
use fastkmeanspp::lsh::{ExactNn, NnOracle};
use fastkmeanspp::rng::Pcg64;

fn dataset(n: usize, d: usize, seed: u64) -> PointSet {
    gaussian_mixture(
        &SynthSpec {
            n,
            d,
            k_true: 20,
            center_spread: 15.0,
            ..Default::default()
        },
        seed,
    )
}

#[test]
fn dist_below_matches_query_practical_mode() {
    // Property: for any threshold, dist_below == (query().dist < t).
    let ps = dataset(800, 12, 1);
    let mut rng = Pcg64::seed_from(2);
    let params = LshParams {
        bucket_width: auto_bucket_width_for_k(&ps, 200, 15, &mut rng),
        ..Default::default()
    };
    let mut lsh = MonotoneLsh::practical(12, &params, &mut rng);
    for i in 0..400u32 {
        lsh.insert(&ps, i);
    }
    let mut checked = 0;
    for q in 400..800 {
        let (_, dist) = lsh.query(&ps, ps.row(q)).unwrap();
        for mult in [0.5f32, 0.999, 1.001, 2.0] {
            let t = dist * mult;
            let got = lsh.dist_below(&ps, ps.row(q), t);
            assert_eq!(
                got,
                dist < t,
                "q={q} t={t} dist={dist} (mult {mult})"
            );
            checked += 1;
        }
    }
    assert!(checked > 1000);
}

#[test]
fn dist_below_matches_query_exact_oracle() {
    let ps = dataset(300, 8, 3);
    let mut nn = ExactNn::default();
    for i in 0..150u32 {
        nn.insert(&ps, i);
    }
    for q in 150..300 {
        let (_, dist) = nn.query(&ps, ps.row(q)).unwrap();
        assert!(nn.dist_below(&ps, ps.row(q), dist * 1.001));
        assert!(!nn.dist_below(&ps, ps.row(q), dist * 0.999));
    }
}

#[test]
fn lsh_exact_while_under_prefix_cap() {
    // While at most PREFIX_CAP points are inserted, MonotoneLsh must be
    // an EXACT nearest-neighbor oracle (the prefix scan covers all).
    let ps = dataset(600, 10, 5);
    let mut rng = Pcg64::seed_from(6);
    let params = LshParams {
        bucket_width: auto_bucket_width_for_k(&ps, 100, 15, &mut rng),
        ..Default::default()
    };
    let mut lsh = MonotoneLsh::practical(10, &params, &mut rng);
    let mut exact = ExactNn::default();
    assert!(PREFIX_CAP >= 100);
    for i in 0..100u32 {
        lsh.insert(&ps, i);
        exact.insert(&ps, i);
    }
    for q in 100..600 {
        let (_, dl) = lsh.query(&ps, ps.row(q)).unwrap();
        let (_, de) = exact.query(&ps, ps.row(q)).unwrap();
        assert!(
            (dl - de).abs() < 1e-5,
            "q={q}: lsh {dl} != exact {de} under the prefix cap"
        );
    }
}

#[test]
fn monotone_past_prefix_cap() {
    // Beyond the cap the oracle goes approximate but must stay monotone.
    let ps = dataset(1500, 10, 7);
    let mut rng = Pcg64::seed_from(8);
    let params = LshParams {
        bucket_width: auto_bucket_width_for_k(&ps, 400, 15, &mut rng),
        ..Default::default()
    };
    let mut lsh = MonotoneLsh::practical(10, &params, &mut rng);
    let queries: Vec<usize> = vec![1400, 1450, 1499];
    let mut last = vec![f32::INFINITY; queries.len()];
    for i in 0..400u32 {
        lsh.insert(&ps, i);
        for (slot, &q) in queries.iter().enumerate() {
            let (_, d) = lsh.query(&ps, ps.row(q)).unwrap();
            assert!(
                d <= last[slot] + 1e-5,
                "q={q} after insert {i}: {d} > {}",
                last[slot]
            );
            last[slot] = d;
        }
    }
}

#[test]
fn rejection_same_seed_same_centers_across_oracle_cost() {
    // The indicator-form accept test must be deterministic in the rng
    // seed (regression guard for the u-draw ordering).
    use fastkmeanspp::seeding::rejection::{rejection_sampling, RejectionConfig};
    let ps = dataset(2000, 16, 9);
    let cfg = RejectionConfig::default();
    let mut a = Pcg64::seed_from(11);
    let mut b = Pcg64::seed_from(11);
    let sa = rejection_sampling(&ps, 40, &cfg, &mut a);
    let sb = rejection_sampling(&ps, 40, &cfg, &mut b);
    assert_eq!(sa.indices, sb.indices);
    assert_eq!(sa.stats.proposals, sb.stats.proposals);
}

// ---------------------------------------------------------------------
// Adversarial oracle suite (LSH-wiring PR): MonotoneLsh (both modes) vs
// ExactNn on duplicate points, coincident centers, zero vectors, and
// d ∈ {1, 8, 127}.
// ---------------------------------------------------------------------

/// Pathological point sets for one dimensionality.
fn adversarial_sets(d: usize) -> Vec<(&'static str, PointSet)> {
    let mut sets = Vec::new();
    // Every point identical: all true NN distances are exactly 0.
    let dup_rows = vec![vec![3.5f32; d]; 40];
    sets.push(("duplicates", PointSet::from_rows(&dup_rows)));
    // Zero vectors mixed with a far duplicate block.
    let mut rows = vec![vec![0.0f32; d]; 12];
    rows.extend(vec![vec![7.25f32; d]; 12]);
    sets.push(("zeros_plus_block", PointSet::from_rows(&rows)));
    // Two coincident tight clusters + one isolated outlier: centers that
    // open on top of each other must keep distance-0 answers.
    let mut rows = Vec::new();
    for i in 0..30 {
        let mut r = vec![0.0f32; d];
        r[0] = if i % 2 == 0 { 10.0 } else { -10.0 };
        rows.push(r);
    }
    rows.push(vec![-50.0f32; d]);
    sets.push(("coincident_clusters", PointSet::from_rows(&rows)));
    sets
}

/// The three oracles under test, freshly built for `ps`.
fn adversarial_oracles(ps: &PointSet, seed: u64) -> Vec<(&'static str, Box<dyn NnOracle>)> {
    let d = ps.dim();
    let mut rng = Pcg64::seed_from(seed);
    let params = LshParams {
        bucket_width: auto_bucket_width_for_k(ps, 8, 15, &mut rng),
        ..Default::default()
    };
    let practical = MonotoneLsh::new(d, &params, &LshMode::Practical, &mut rng);
    let rigorous = MonotoneLsh::new(
        d,
        &params,
        &LshMode::Rigorous {
            // All-duplicate sets have max_dist 0; the floor keeps the
            // rigorous scale layout non-degenerate.
            max_dist: ps.max_dist_upper_bound().max(1.0),
            delta: (ps.len() * d) as f32,
        },
        &mut rng,
    );
    vec![
        ("exact", Box::new(ExactNn::default()) as Box<dyn NnOracle>),
        ("lsh-practical", Box::new(practical)),
        ("lsh-rigorous", Box::new(rigorous)),
    ]
}

/// Brute-force true NN distance from `q` to the inserted set.
fn true_nn(ps: &PointSet, inserted: &[u32], q: usize) -> f32 {
    inserted
        .iter()
        .map(|&i| ps.d2_rows(q, i as usize).sqrt())
        .fold(f32::INFINITY, f32::min)
}

#[test]
fn adversarial_soundness_and_prefix_exactness() {
    // On every pathological set, every oracle must (a) never report a
    // distance below the true NN distance (candidates are real inserted
    // points), and (b) be EXACT while at most PREFIX_CAP centers are
    // open — these sets all fit under the cap, so the approximation
    // bound degenerates to equality for the LSH modes too.
    for d in [1usize, 8, 127] {
        for (set_name, ps) in adversarial_sets(d) {
            let n = ps.len();
            let half: Vec<u32> = (0..(n as u32) / 2).collect();
            assert!(half.len() <= PREFIX_CAP);
            let norms = squared_norms(&ps);
            for (oracle_name, mut oracle) in adversarial_oracles(&ps, 7 + d as u64) {
                assert!(oracle.query(&ps, ps.row(0)).is_none());
                for &i in &half {
                    oracle.insert(&ps, i);
                }
                assert_eq!(oracle.len(), half.len());
                for q in 0..n {
                    let (_, got) = oracle.query(&ps, ps.row(q)).unwrap();
                    let want = true_nn(&ps, &half, q);
                    let ctx = format!("{set_name}/{oracle_name} d={d} q={q}");
                    assert!(got + 1e-4 >= want, "{ctx}: reported {got} below true {want}");
                    assert!(
                        (got - want).abs() <= 1e-4 * want.max(1.0),
                        "{ctx}: not exact under the prefix cap ({got} vs {want})"
                    );
                    // Witness-scan agreement with the true NN distance at
                    // thresholds off the f32 knife edge (under the cap the
                    // prefix scan makes every oracle's indicator exact),
                    // for both the reference and the norm-cached paths.
                    for t in [want * 0.5, want + 1.0, 0.25, 100.0] {
                        if !(t > 0.0) {
                            continue;
                        }
                        let reference = oracle.dist_below(&ps, ps.row(q), t);
                        assert_eq!(reference, want < t, "{ctx}: dist_below at t={t}");
                        let cached = oracle.dist_below_cached(&ps, ps.row(q), norms[q], t);
                        assert_eq!(cached, reference, "{ctx}: cached vs reference at t={t}");
                    }
                }
            }
        }
    }
}

#[test]
fn adversarial_monotone_contract() {
    // The monotone contract — DIST(q, Query(q)) never increases as more
    // centers open — must survive duplicate inserts, coincident centers
    // and zero vectors in every mode, and self-queries must end at 0.
    for d in [1usize, 8, 127] {
        for (set_name, ps) in adversarial_sets(d) {
            let n = ps.len();
            for (oracle_name, mut oracle) in adversarial_oracles(&ps, 100 + d as u64) {
                let probes = [n - 1, n / 2, 0];
                let mut last = [f32::INFINITY; 3];
                for i in 0..n as u32 {
                    oracle.insert(&ps, i);
                    for (slot, &q) in probes.iter().enumerate() {
                        let (_, dd) = oracle.query(&ps, ps.row(q)).unwrap();
                        assert!(
                            dd <= last[slot] + 1e-5,
                            "{set_name}/{oracle_name} d={d} q={q}: {dd} > {} after insert {i}",
                            last[slot]
                        );
                        last[slot] = dd;
                    }
                }
                for q in [0, n - 1] {
                    let (_, dd) = oracle.query(&ps, ps.row(q)).unwrap();
                    assert!(dd <= 1e-4, "{set_name}/{oracle_name} d={d}: self-query {dd}");
                }
            }
        }
    }
}

#[test]
fn rejection_on_adversarial_sets_returns_k_distinct_all_oracles() {
    // End-to-end: the seeder must deliver k distinct centers on the
    // pathological sets with every oracle (duplicates exhaust the
    // multi-tree weights, exercising the deterministic top-up path).
    use fastkmeanspp::seeding::rejection::{rejection_sampling, OracleKind, RejectionConfig};
    for d in [1usize, 8] {
        for (set_name, ps) in adversarial_sets(d) {
            for oracle in OracleKind::all() {
                let cfg = RejectionConfig {
                    oracle,
                    ..Default::default()
                };
                let mut rng = Pcg64::seed_from(5);
                let k = ps.len().min(10);
                let s = rejection_sampling(&ps, k, &cfg, &mut rng);
                assert_eq!(s.k(), k, "{set_name} d={d} {oracle:?}");
                let mut idx = s.indices.clone();
                idx.sort_unstable();
                idx.dedup();
                assert_eq!(idx.len(), k, "{set_name} d={d} {oracle:?} returned duplicates");
            }
        }
    }
}

#[test]
fn rejection_distribution_unchanged_by_indicator_form() {
    // With the EXACT oracle and c=1 the accepted second-center marginal
    // must match the analytic D^2 distribution — the indicator-form
    // evaluation must not shift it (this is the Lemma 5.2 check).
    use fastkmeanspp::seeding::rejection::{rejection_sampling, OracleKind, RejectionConfig};
    let rows = vec![
        vec![0.0f32, 0.0],
        vec![2.0, 0.0],
        vec![0.0, 3.0],
        vec![8.0, 8.0],
    ];
    let ps = PointSet::from_rows(&rows);
    let cfg = RejectionConfig {
        c: 1.0,
        oracle: OracleKind::Exact,
        ..Default::default()
    };
    let trials = 40_000;
    let mut first = vec![0.0f64; 4];
    let mut second = vec![0.0f64; 4];
    for seed in 0..trials {
        let mut rng = Pcg64::seed_from(seed);
        let s = rejection_sampling(&ps, 2, &cfg, &mut rng);
        first[s.indices[0]] += 1.0;
        second[s.indices[1]] += 1.0;
    }
    let mut want = vec![0.0f64; 4];
    for f in 0..4 {
        let d2s: Vec<f64> = (0..4).map(|j| ps.d2_rows(j, f) as f64).collect();
        let sum: f64 = d2s.iter().sum();
        for j in 0..4 {
            want[j] += (first[f] / trials as f64) * d2s[j] / sum;
        }
    }
    for j in 0..4 {
        let got = second[j] / trials as f64;
        assert!(
            (got - want[j]).abs() < 0.012,
            "j={j} got={got} want={}",
            want[j]
        );
    }
}
