"""AOT pipeline tests: lowering produces parseable HLO text + manifest.

Executes the same lowering path as `make artifacts` on one small variant
per entry point and re-runs the HLO through xla_client to verify it is
self-contained (no Mosaic custom-calls — the interpret=True guarantee).
"""

from __future__ import annotations

import numpy as np

from compile import aot, model


def _lower(name, fn, chunk=64, d=8, k=4):
    needs_k = name != "d2_update"
    return aot.lower_variant(name, fn, chunk, d, k if needs_k else None)


def test_all_entry_points_lower_to_hlo_text():
    for name, fn, _needs_k in aot.ENTRY_POINTS:
        text = _lower(name, fn)
        assert "HloModule" in text
        assert "custom-call" not in text.lower(), (
            f"{name}: Mosaic custom-call leaked into HLO — interpret=True "
            "must lower to plain HLO for the CPU PJRT client"
        )


def test_hlo_text_parses_back():
    # The text must round-trip through XLA's HLO parser — this is exactly
    # the entry point the rust runtime uses (HloModuleProto::from_text_file).
    # Full compile+execute of the text is covered by the rust integration
    # test `runtime_pjrt_matches_native`.
    from jax._src.lib import xla_client as xc

    text = _lower("cost", model.cost_fn, chunk=32, d=4, k=2)
    module = xc._xla.hlo_module_from_text(text)
    reparsed = module.as_serialized_hlo_module_proto()
    assert len(reparsed) > 0
    # Entry computation keeps the chunk-shaped parameters.
    assert "f32[32,4]" in module.to_string()


def test_manifest_grid_shapes():
    # The variant naming contract the rust manifest loader parses.
    text = _lower("assign", model.assign_fn, chunk=128, d=16, k=8)
    assert "f32[128,16]" in text and "f32[8,16]" in text
