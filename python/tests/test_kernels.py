"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (B, D, K incl. non-tile-divisible Bs), value
scales and degenerate cases; every property asserts allclose against
ref.py. This is the CORE correctness signal for the compute layer.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import d2_update, pairwise_d2, ref

RNG = np.random.default_rng(1234)


def _pts(b, d, scale=1.0, seed=0):
    return (np.random.default_rng(seed).normal(size=(b, d)) * scale).astype(
        np.float32
    )


# ---------------------------------------------------------------- pairwise


@settings(max_examples=40, deadline=None)
@given(
    b=st.sampled_from([1, 3, 17, 64, 512, 513, 1024]),
    d=st.integers(min_value=1, max_value=96),
    k=st.sampled_from([1, 2, 7, 32, 128]),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pairwise_d2_matches_ref(b, d, k, scale, seed):
    x = _pts(b, d, scale, seed)
    c = _pts(k, d, scale, seed + 1)
    got = np.asarray(pairwise_d2(x, c))
    want = np.asarray(ref.pairwise_d2_ref(x, c))
    # matmul form loses ~half the mantissa relative to the diff form at
    # large |x|; tolerance is scale-aware.
    tol = 1e-3 * max(scale * scale, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=tol)


def test_pairwise_d2_zero_distance_diagonal():
    x = _pts(32, 9, seed=7)
    d2 = np.asarray(pairwise_d2(x, x))
    assert np.allclose(np.diag(d2), 0.0, atol=1e-3)
    assert (d2 >= 0).all(), "kernel must clamp matmul-form negatives"


def test_pairwise_d2_identical_points():
    x = np.ones((16, 5), dtype=np.float32)
    c = np.ones((3, 5), dtype=np.float32)
    np.testing.assert_allclose(np.asarray(pairwise_d2(x, c)), 0.0, atol=1e-5)


def test_pairwise_d2_block_divisible_grid():
    # B an exact multiple of the 512 tile -> multi-step grid path.
    x = _pts(2048, 24, seed=3)
    c = _pts(64, 24, seed=4)
    np.testing.assert_allclose(
        np.asarray(pairwise_d2(x, c)),
        np.asarray(ref.pairwise_d2_ref(x, c)),
        rtol=1e-3,
        atol=1e-3,
    )


# ---------------------------------------------------------------- d2_update


@settings(max_examples=40, deadline=None)
@given(
    b=st.sampled_from([1, 5, 100, 1024, 1025, 4096]),
    d=st.integers(min_value=1, max_value=96),
    scale=st.sampled_from([1e-2, 1.0, 1e2]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_d2_update_matches_ref(b, d, scale, seed):
    x = _pts(b, d, scale, seed)
    c = _pts(1, d, scale, seed + 1)[0]
    cur = (np.random.default_rng(seed + 2).uniform(0, 4 * scale * scale, b)).astype(
        np.float32
    )
    got = np.asarray(d2_update(x, c, cur))
    want = np.asarray(ref.d2_update_ref(x, c, cur))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)


def test_d2_update_never_increases():
    x = _pts(512, 13, seed=11)
    c = _pts(1, 13, seed=12)[0]
    cur = np.full(512, 1e-6, dtype=np.float32)
    got = np.asarray(d2_update(x, c, cur))
    assert (got <= cur + 1e-12).all()


def test_d2_update_inf_start_equals_exact_distance():
    x = _pts(256, 8, seed=13)
    c = x[17].copy()
    cur = np.full(256, np.finfo(np.float32).max, dtype=np.float32)
    got = np.asarray(d2_update(x, c, cur))
    want = ((x - c) ** 2).sum(1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert got[17] == pytest.approx(0.0, abs=1e-6)


def test_d2_update_idempotent():
    x = _pts(128, 6, seed=21)
    c = _pts(1, 6, seed=22)[0]
    cur = np.full(128, 1e9, dtype=np.float32)
    once = np.asarray(d2_update(x, c, cur))
    twice = np.asarray(d2_update(x, c, once))
    np.testing.assert_allclose(once, twice, rtol=0, atol=0)
