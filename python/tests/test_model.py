"""L2 correctness: model entry points (shapes + semantics vs numpy).

Includes the padding contract the rust runtime relies on: zero dim-padding
preserves distances; PAD_CENTER_COORD rows never win an argmin and attract
no Lloyd mass.
"""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _case(n, d, k, seed=0):
    rng = np.random.default_rng(seed)
    return (
        rng.normal(size=(n, d)).astype(np.float32),
        rng.normal(size=(k, d)).astype(np.float32),
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([4, 64, 300, 1024]),
    d=st.integers(min_value=1, max_value=64),
    k=st.sampled_from([1, 3, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_assign_matches_numpy(n, d, k, seed):
    pts, cs = _case(n, d, k, seed)
    idx, mind2 = model.assign_fn(pts, cs)
    d2 = ((pts[:, None, :] - cs[None, :, :]) ** 2).sum(-1)
    want_min = d2.min(1)
    np.testing.assert_allclose(np.asarray(mind2), want_min, rtol=1e-3, atol=1e-3)
    # argmin may legitimately differ under ties/eps — check via distance.
    got_val = d2[np.arange(n), np.asarray(idx)]
    np.testing.assert_allclose(got_val, want_min, rtol=1e-3, atol=1e-3)
    assert np.asarray(idx).dtype == np.int32


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 256, 1000]),
    d=st.integers(min_value=1, max_value=48),
    k=st.sampled_from([2, 8, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lloyd_step_matches_ref(n, d, k, seed):
    pts, cs = _case(n, d, k, seed)
    sums, counts, cost = model.lloyd_step_fn(pts, cs)
    rsums, rcounts, rcost = ref.lloyd_step_ref(pts, cs)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(rcounts))
    np.testing.assert_allclose(float(cost), float(rcost), rtol=1e-3)
    # conservation: every point lands in exactly one cluster
    assert float(np.asarray(counts).sum()) == n
    np.testing.assert_allclose(
        np.asarray(sums).sum(0), pts.sum(0), rtol=1e-3, atol=1e-2
    )


def test_cost_fn_equals_assign_sum():
    pts, cs = _case(500, 20, 10, seed=42)
    (cost,) = model.cost_fn(pts, cs)
    _, mind2 = model.assign_fn(pts, cs)
    np.testing.assert_allclose(float(cost), float(np.asarray(mind2).sum()), rtol=1e-5)


def test_d2_update_fn_tuple_contract():
    pts, cs = _case(128, 12, 1, seed=5)
    cur = np.full(128, 1e30, dtype=np.float32)
    (out,) = model.d2_update_fn(pts, cs[:1], cur)
    want = ((pts - cs[0]) ** 2).sum(1)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- padding contract


def test_zero_dim_padding_preserves_distances():
    pts, cs = _case(200, 30, 7, seed=9)
    pad = lambda a, d: np.concatenate(
        [a, np.zeros((a.shape[0], d - a.shape[1]), np.float32)], axis=1
    )
    _, m1 = model.assign_fn(pts, cs)
    _, m2 = model.assign_fn(pad(pts, 96), pad(cs, 96))
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-4, atol=1e-4)


def test_pad_center_rows_never_selected():
    pts, cs = _case(300, 16, 4, seed=10)
    padded = np.concatenate(
        [cs, np.full((60, 16), model.PAD_CENTER_COORD, np.float32)], axis=0
    )
    idx, mind2 = model.assign_fn(pts, padded)
    assert (np.asarray(idx) < 4).all()
    _, want = model.assign_fn(pts, cs)
    np.testing.assert_allclose(np.asarray(mind2), np.asarray(want), rtol=1e-4)
    # Lloyd: padded rows attract zero mass
    sums, counts, _ = model.lloyd_step_fn(pts, padded)
    assert np.asarray(counts)[4:].sum() == 0
    assert np.abs(np.asarray(sums)[4:]).sum() == 0
