"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with nothing but `jax.numpy` so that pytest/hypothesis can compare
the two with `assert_allclose`. These functions are also used directly by
`model.py` shape tests.

All distance algebra is squared Euclidean, matching the paper's D^2
sampling (`DIST(x, C)^2`).
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_d2_ref(points: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """Exact [B, K] squared-distance matrix between points [B, D] and centers [K, D].

    Computed the numerically-straightforward way (explicit difference) so it
    can serve as an oracle for the matmul-form kernel.
    """
    diff = points[:, None, :] - centers[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def d2_update_ref(
    points: jnp.ndarray, center: jnp.ndarray, cur_d2: jnp.ndarray
) -> jnp.ndarray:
    """min(cur_d2, ||x - center||^2) per point — the k-means++ inner loop."""
    diff = points - center[None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    return jnp.minimum(cur_d2, d2)


def assign_ref(points: jnp.ndarray, centers: jnp.ndarray):
    """(argmin index [B] int32, min squared distance [B] f32)."""
    d2 = pairwise_d2_ref(points, centers)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def lloyd_step_ref(points: jnp.ndarray, centers: jnp.ndarray):
    """One Lloyd step over a chunk of points.

    Returns (sums [K, D], counts [K], cost scalar): per-cluster coordinate
    sums and member counts for the chunk (the caller reduces over chunks and
    divides), plus the chunk's k-means cost under the *current* centers.
    """
    idx, mind2 = assign_ref(points, centers)
    k = centers.shape[0]
    one_hot = (idx[:, None] == jnp.arange(k)[None, :]).astype(points.dtype)
    sums = one_hot.T @ points
    counts = jnp.sum(one_hot, axis=0)
    cost = jnp.sum(mind2)
    return sums, counts, cost
