"""L1 Pallas kernel: tiled point <-> center squared-distance matrix.

The compute hot-spot of exact D^2 seeding, Lloyd refinement and cost
evaluation is the dense `[B, D] x [K, D] -> [B, K]` squared-distance
matrix. On TPU the right formulation is the matmul (MXU) form

    d2[b, k] = ||x_b||^2 + ||c_k||^2 - 2 <x_b, c_k>

tiled so that a `(BLOCK_B, D)` point tile plus the full `(K, D)` center
panel sit in VMEM while the inner contraction runs on the systolic array.
The grid is 1-D over point tiles — the HBM->VMEM pipeline the paper's CPU
code gets from cache blocking is expressed by the BlockSpec index_map.

`interpret=True` is mandatory on this image: the CPU PJRT plugin cannot run
Mosaic custom-calls; interpret mode lowers the kernel to plain HLO so the
rust runtime can execute it. Real-TPU perf is *estimated* in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default point-tile height. 512 x 96 f32 point tile (192 KiB) + 1024 x 96
# center panel (384 KiB) + 512 x 1024 f32 out tile (2 MiB) ~ 2.6 MiB VMEM:
# comfortably inside a 16 MiB TPU core budget with double buffering.
DEFAULT_BLOCK_B = 512


def _pairwise_d2_kernel(x_ref, c_ref, o_ref):
    """o[b, k] = ||x_b - c_k||^2 for one point tile against all centers."""
    x = x_ref[...]  # [BLOCK_B, D]
    c = c_ref[...]  # [K, D]
    # MXU-form: the contraction is a plain matmul; the norms are VPU work.
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # [BLOCK_B, 1]
    cc = jnp.sum(c * c, axis=1, keepdims=True).T  # [1, K]
    xc = jax.lax.dot_general(
        x,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [BLOCK_B, K]
    # Clamp at zero: the matmul form can go slightly negative for near-
    # duplicate points; distances are non-negative by definition.
    o_ref[...] = jnp.maximum(xx + cc - 2.0 * xc, 0.0)


@functools.partial(jax.jit, static_argnames=("block_b",))
def pairwise_d2(
    points: jnp.ndarray, centers: jnp.ndarray, *, block_b: int = DEFAULT_BLOCK_B
) -> jnp.ndarray:
    """[B, K] squared distances; B must be a multiple of `block_b`."""
    b, d = points.shape
    k, d2 = centers.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    if b % block_b != 0:
        # Small inputs (tests, quickstart variants): fall back to one tile.
        block_b = b
    grid = (b // block_b,)
    return pl.pallas_call(
        _pairwise_d2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(points.astype(jnp.float32), centers.astype(jnp.float32))
