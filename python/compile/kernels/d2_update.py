"""L1 Pallas kernel: fused k-means++ distance min-update.

After opening a new center c, every point's cached squared distance to the
center set shrinks to `min(cur_d2[x], ||x - c||^2)`. This is the inner loop
of exact D^2 seeding (the paper's Theta(ndk) baseline) — one fused pass,
no [B, K] intermediate.

Tiled over points with a 1-D grid; the single center row is re-fetched into
VMEM for every tile (BlockSpec index_map pins it to block 0). VMEM per
step ~ BLOCK_B*D + D + 2*BLOCK_B floats.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 1024


def _d2_update_kernel(x_ref, c_ref, cur_ref, o_ref):
    x = x_ref[...]  # [BLOCK_B, D]
    c = c_ref[...]  # [1, D]
    diff = x - c  # broadcast over the tile
    d2 = jnp.sum(diff * diff, axis=1)  # [BLOCK_B]
    o_ref[...] = jnp.minimum(cur_ref[...], d2)


@functools.partial(jax.jit, static_argnames=("block_b",))
def d2_update(
    points: jnp.ndarray,
    center: jnp.ndarray,
    cur_d2: jnp.ndarray,
    *,
    block_b: int = DEFAULT_BLOCK_B,
) -> jnp.ndarray:
    """min(cur_d2, ||x - center||^2) per point; B a multiple of block_b."""
    b, d = points.shape
    assert center.shape == (d,), f"center shape {center.shape} != ({d},)"
    assert cur_d2.shape == (b,)
    if b % block_b != 0:
        block_b = b
    grid = (b // block_b,)
    return pl.pallas_call(
        _d2_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(
        points.astype(jnp.float32),
        center.astype(jnp.float32).reshape(1, d),
        cur_d2.astype(jnp.float32),
    )
