"""L1 Pallas kernels + pure-jnp oracles.

Exports:
  pairwise_d2 — tiled [B,D]x[K,D]->[B,K] squared-distance kernel (MXU form)
  d2_update   — fused k-means++ distance min-update
  ref         — jnp reference implementations (ground truth for pytest)
"""

from . import ref  # noqa: F401
from .d2_update import d2_update  # noqa: F401
from .pairwise_d2 import pairwise_d2  # noqa: F401
