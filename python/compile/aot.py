"""AOT pipeline: lower every L2 entry point to HLO *text* + manifest.

Run once at build time (`make artifacts`); rust/src/runtime/ loads the
results via `HloModuleProto::from_text_file` and executes them on the PJRT
CPU client. HLO text (NOT `lowered.compile().serialize()` / proto bytes)
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Shape variants
--------------
PJRT executables are shape-specialized, so we emit one module per
(entry point, chunk, d, k) variant. The rust runtime pads:
  * the point dim to the variant's `d` with zeros (zero-padded coordinates
    on BOTH points and centers add 0 to every distance);
  * the chunk tail with copies of an arbitrary real point (ignored or
    subtracted by the caller);
  * unused center rows with PAD_CENTER_COORD (never argmin-selected).

Variant grid: chunk 16384 (streaming) and 2048 (small/test), d in
{32, 96, 128}, k in {128, 1024}. d=96 covers the paper datasets
(74/90/68 pad up); d=32 the examples; d=128 headroom.

Usage: python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model

# (name, fn, needs_k)
ENTRY_POINTS = [
    ("d2_update", model.d2_update_fn, False),
    ("assign", model.assign_fn, True),
    ("lloyd_step", model.lloyd_step_fn, True),
    ("cost", model.cost_fn, True),
]

CHUNKS = [2048, 16384]
DIMS = [32, 96, 128]
KS = [128, 1024]

# --quick trims the grid for CI-speed builds (still enough for all tests
# and the scaled-profile benches).
QUICK_CHUNKS = [2048, 16384]
QUICK_DIMS = [32, 96]
QUICK_KS = [128, 1024]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name: str, fn, chunk: int, d: int, k: int | None) -> str:
    f32 = jax.ShapeDtypeStruct((chunk, d), "float32")
    if name == "d2_update":
        args = (f32, jax.ShapeDtypeStruct((1, d), "float32"),
                jax.ShapeDtypeStruct((chunk,), "float32"))
    else:
        args = (f32, jax.ShapeDtypeStruct((k, d), "float32"))
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="trimmed variant grid (CI builds)")
    ns = ap.parse_args()

    chunks = QUICK_CHUNKS if ns.quick else CHUNKS
    dims = QUICK_DIMS if ns.quick else DIMS
    ks = QUICK_KS if ns.quick else KS

    os.makedirs(ns.out_dir, exist_ok=True)
    manifest_rows = []
    total = 0
    for name, fn, needs_k in ENTRY_POINTS:
        for chunk in chunks:
            for d in dims:
                k_list = ks if needs_k else [0]
                for k in k_list:
                    variant = (
                        f"{name}_n{chunk}_d{d}" + (f"_k{k}" if needs_k else "")
                    )
                    path = f"{variant}.hlo.txt"
                    text = lower_variant(name, fn, chunk, d, k if needs_k else None)
                    with open(os.path.join(ns.out_dir, path), "w") as f:
                        f.write(text)
                    manifest_rows.append(
                        (name, path, str(chunk), str(d), str(k))
                    )
                    total += len(text)
                    print(f"  {variant}: {len(text)} chars", file=sys.stderr)

    # Hand-rolled TSV manifest (no serde on the rust side either):
    # entry \t file \t chunk \t d \t k    — k=0 for k-independent entries.
    with open(os.path.join(ns.out_dir, "manifest.tsv"), "w") as f:
        f.write("# entry\tfile\tchunk\td\tk\n")
        for row in manifest_rows:
            f.write("\t".join(row) + "\n")
    print(
        f"wrote {len(manifest_rows)} HLO modules ({total} chars) "
        f"+ manifest.tsv to {ns.out_dir}",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
