"""L2: jax compute graphs built on the L1 Pallas kernels.

These are the dense-compute entry points the rust coordinator executes via
PJRT (AOT-lowered to HLO text by aot.py, loaded by rust/src/runtime/).
Python is build-time only: nothing in this package is imported at runtime.

Entry points (all chunk-shaped — the rust side streams fixed-size chunks
and pads the tail):

  d2_update_fn(points [N,D], center [1,D], cur [N])        -> (new_cur [N],)
  assign_fn(points [N,D], centers [K,D])                   -> (idx [N] i32, mind2 [N])
  lloyd_step_fn(points [N,D], centers [K,D])               -> (sums [K,D], counts [K], cost [])
  cost_fn(points [N,D], centers [K,D])                     -> (cost [],)

Padding contract with the rust side (see rust/src/runtime/pjrt.rs):
  * tail point rows are padded with the dataset's first point; the rust
    side subtracts the padded rows' contribution (it knows the pad count);
    for `assign`/`d2_update` it simply ignores the padded outputs.
  * unused center rows are padded with the PAD_CENTER_COORD sentinel so
    they are never the argmin and attract no Lloyd mass.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import d2_update, pairwise_d2

# Sentinel coordinate for padded center rows. Distance contribution per
# dim ~ (1e15)^2 = 1e30; times d<=128 dims ~ 1e32 — far above any real
# distance yet far below f32 overflow (3.4e38).
PAD_CENTER_COORD = 1.0e15


def d2_update_fn(points, center, cur_d2):
    """k-means++ inner loop: new cached D^2 after opening `center`."""
    return (d2_update(points, center.reshape(-1), cur_d2),)


def assign_fn(points, centers):
    """Nearest-center assignment: (index [N] i32, min D^2 [N] f32)."""
    d2 = pairwise_d2(points, centers)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind2 = jnp.min(d2, axis=1)
    return idx, mind2


def lloyd_step_fn(points, centers):
    """One Lloyd step over a chunk: per-cluster sums/counts + current cost.

    The one-hot contraction is a [K,N]x[N,D] matmul — MXU-shaped, fused by
    XLA with the assignment's argmin into a single pass over the chunk.
    """
    idx, mind2 = assign_fn(points, centers)
    k = centers.shape[0]
    one_hot = (idx[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(
        jnp.float32
    )
    sums = jnp.einsum("nk,nd->kd", one_hot, points)
    counts = jnp.sum(one_hot, axis=0)
    cost = jnp.sum(mind2)
    return sums, counts, cost


def cost_fn(points, centers):
    """Chunk k-means cost under `centers` (sum of min squared distances)."""
    _, mind2 = assign_fn(points, centers)
    return (jnp.sum(mind2),)
