"""Build-time-only compile package: L2 jax model + L1 Pallas kernels + AOT.

Nothing here is imported at runtime; `make artifacts` runs `compile.aot`
once and the rust binary is self-contained afterwards.
"""
