//! Large-k use case from the paper's introduction: near-duplicate
//! detection. Each latent *group* is a tight bundle of near-identical
//! items; clustering with k = #groups should put one center in (almost)
//! every group. This is exactly the "large k" regime (k in the thousands)
//! the paper's speedups target.
//!
//! We compare rejection sampling against uniform seeding on *group
//! coverage* (fraction of groups receiving a center) and wall-clock.
//!
//! ```bash
//! cargo run --release --example near_duplicates
//! GROUPS=3000 PER=8 cargo run --release --example near_duplicates
//! ```

use std::collections::HashSet;
use std::time::Instant;

use fastkmeanspp::prelude::*;
use fastkmeanspp::seeding::SeedingAlgorithm;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let groups = env_usize("GROUPS", 1500);
    let per = env_usize("PER", 10);
    let d = env_usize("D", 48);
    let seed = env_usize("SEED", 11) as u64;

    // Build the near-duplicate corpus: group centers far apart, members
    // within a tiny radius (hash-like feature vectors of documents).
    let mut rng = Pcg64::seed_from(seed);
    let mut rows = Vec::with_capacity(groups * per);
    for _ in 0..groups {
        let center: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 50.0).collect();
        for _ in 0..per {
            rows.push(
                center
                    .iter()
                    .map(|&c| c + rng.next_gaussian() as f32 * 0.05)
                    .collect::<Vec<f32>>(),
            );
        }
    }
    let data = fastkmeanspp::data::matrix::PointSet::from_rows(&rows);
    println!(
        "near-duplicate corpus: {} items in {} groups of {} (d={d})",
        data.len(),
        groups,
        per
    );

    let k = groups;
    for algo in [
        SeedingAlgorithm::Rejection,
        SeedingAlgorithm::FastKMeansPP,
        SeedingAlgorithm::Uniform,
    ] {
        let mut rng = Pcg64::seed_from(seed + 1);
        let t0 = Instant::now();
        let seeding = algo.run(&data, k, &mut rng);
        let secs = t0.elapsed().as_secs_f64();
        let covered: HashSet<usize> = seeding.indices.iter().map(|&i| i / per).collect();
        let coverage = covered.len() as f64 / groups as f64;
        println!(
            "{:<16} {:>8.3}s  group coverage {:>5.1}% ({} duplicates wasted)",
            algo.name(),
            secs,
            100.0 * coverage,
            k - covered.len()
        );
    }
    println!(
        "\nExpected shape: D^2-family coverage near 100% (each new center lands in an\n\
         uncovered far-away group); uniform coverage ~{:.0}% (1 - 1/e for k = groups).",
        100.0 * (1.0 - (-1.0f64).exp())
    );
}
