//! "Solutions for all k = 1..n from one run" (Corollary 5.5): the centers
//! FASTK-MEANS++ opens form a *nested* sequence — the first k opened
//! points are a valid D^2-seeding for every k. One `O(nd log(dΔ))` run
//! therefore yields the entire cost-vs-k curve, something the Θ(ndk)
//! baseline cannot do without k separate runs.
//!
//! This example produces the curve from a single run and spot-checks a
//! few k against independently run exact k-means++.
//!
//! ```bash
//! cargo run --release --example all_k_sweep
//! ```

use std::time::Instant;

use fastkmeanspp::lloyd::cost_native;
use fastkmeanspp::prelude::*;
use fastkmeanspp::seeding::{fastkmeanspp::fast_kmeanspp, kmeanspp::kmeanspp};

fn main() {
    let data = fastkmeanspp::data::synth::gaussian_mixture(
        &SynthSpec {
            n: 30_000,
            d: 24,
            k_true: 256,
            center_spread: 10.0,
            ..SynthSpec::default()
        },
        0xA11_4B,
    );
    let k_max = 2048;
    println!("n={} d={}; one FastKMeans++ run at k={k_max}", data.len(), data.dim());

    let mut rng = Pcg64::seed_from(99);
    let t0 = Instant::now();
    let seeding = fast_kmeanspp(&data, k_max, &Default::default(), &mut rng);
    let one_run = t0.elapsed().as_secs_f64();
    println!("single run: {one_run:.2}s -> nested solutions for every k <= {k_max}\n");

    println!("| k | cost (prefix of one run) | cost (fresh exact k-means++) | fresh seconds |");
    println!("|---|---|---|---|");
    for k in [16usize, 64, 256, 1024, 2048] {
        let prefix = data.gather(&seeding.indices[..k]);
        let prefix_cost = cost_native(&data, &prefix);
        let mut rng2 = Pcg64::seed_from(100 + k as u64);
        let t = Instant::now();
        let fresh = kmeanspp(&data, k, &mut rng2);
        let fresh_secs = t.elapsed().as_secs_f64();
        let fresh_cost = cost_native(&data, &fresh.centers);
        println!("| {k} | {prefix_cost:.4e} | {fresh_cost:.4e} | {fresh_secs:.2}s |");
    }
    println!(
        "\nThe whole middle column cost ONE {one_run:.2}s run; the right column pays \
         Θ(ndk) per k."
    );
}
