//! Quickstart: seed a clustered dataset with the paper's rejection
//! sampler, compare against exact k-means++, refine with Lloyd.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fastkmeanspp::lloyd::{cost_native, lloyd, LloydConfig};
use fastkmeanspp::prelude::*;
use fastkmeanspp::runtime::Backend;
use fastkmeanspp::seeding::SeedingAlgorithm;

fn main() -> fastkmeanspp::error::Result<()> {
    // 20k points in 32 dims, 100 latent clusters.
    let data = fastkmeanspp::data::synth::gaussian_mixture(
        &SynthSpec {
            n: 20_000,
            d: 32,
            k_true: 100,
            center_spread: 12.0,
            ..SynthSpec::default()
        },
        0xC0FFEE,
    );
    println!("dataset: n={} d={}", data.len(), data.dim());

    let k = 100;
    for algo in [
        SeedingAlgorithm::Rejection,
        SeedingAlgorithm::FastKMeansPP,
        SeedingAlgorithm::KMeansPP,
        SeedingAlgorithm::Uniform,
    ] {
        let mut rng = Pcg64::seed_from(42);
        let t0 = std::time::Instant::now();
        let seeding = algo.run(&data, k, &mut rng);
        let secs = t0.elapsed().as_secs_f64();
        let cost = cost_native(&data, &seeding.centers);
        println!(
            "{:<16} k={k}  {:>8.3}s  seeding cost = {cost:.4e}",
            algo.name(),
            secs
        );
    }

    // Refine the rejection seeding with Lloyd (PJRT backend if artifacts
    // are built, native otherwise).
    let mut rng = Pcg64::seed_from(42);
    let seeding = SeedingAlgorithm::Rejection.run(&data, k, &mut rng);
    let backend = Backend::auto(std::path::Path::new("artifacts"));
    let refined = lloyd(&data, &seeding.centers, &LloydConfig::default(), &backend)?;
    println!(
        "lloyd ({}): {} iters, cost {:.4e} -> {:.4e}",
        backend.name(),
        refined.iterations,
        refined.history.first().unwrap(),
        refined.history.last().unwrap()
    );
    Ok(())
}
