//! End-to-end driver: the full system on a real (synthetic-census-scale)
//! workload, proving all layers compose:
//!
//!   dataset registry -> Appendix-F quantization -> all four seeders
//!   -> cost evaluation (PJRT backend when artifacts are built)
//!   -> Lloyd refinement -> paper-style runtime/cost table.
//!
//! This regenerates the *shape* of the paper's headline result (Tables
//! 3/6 rows for the census dataset): FASTK-MEANS++ / REJECTIONSAMPLING
//! runtimes nearly flat in k while K-MEANS++ grows linearly, at
//! equivalent solution cost. The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example end_to_end_census            # scaled n
//! N=30000 K=100,500 cargo run --release --example end_to_end_census
//! ```

use std::time::Instant;

use fastkmeanspp::data::quantize::quantize;
use fastkmeanspp::data::synth::census_sim;
use fastkmeanspp::lloyd::{lloyd, LloydConfig};
use fastkmeanspp::prelude::*;
use fastkmeanspp::runtime::Backend;
use fastkmeanspp::seeding::SeedingAlgorithm;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> fastkmeanspp::error::Result<()> {
    let n = env_usize("N", 60_000);
    let ks: Vec<usize> = std::env::var("K")
        .unwrap_or_else(|_| "100,500,1000".into())
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let seed = env_usize("SEED", 7) as u64;

    eprintln!("generating census_sim n={n} d=68 ...");
    let t0 = Instant::now();
    let original = census_sim(n, seed);
    eprintln!("generated in {:.1}s", t0.elapsed().as_secs_f64());

    // Appendix-F quantization (seeding space); costs on original coords.
    let mut qrng = Pcg64::seed_from(seed ^ 0xF00D);
    let q = quantize(&original, &mut qrng);
    let backend = Backend::auto(std::path::Path::new("artifacts"));
    eprintln!("cost backend: {}", backend.name());

    let algos = [
        SeedingAlgorithm::FastKMeansPP,
        SeedingAlgorithm::Rejection,
        SeedingAlgorithm::KMeansPP,
        SeedingAlgorithm::Afkmc2,
        SeedingAlgorithm::Uniform,
    ];

    println!("\n| algorithm | k | seconds | vs fast | seeding cost | cost vs k-means++ |");
    println!("|---|---|---|---|---|---|");
    for &k in &ks {
        let mut fast_secs = None;
        let mut pp_cost = None;
        let mut rows = Vec::new();
        for algo in algos {
            let mut rng = Pcg64::seed_from(seed + k as u64);
            let t = Instant::now();
            let seeding = algo.run(&q.points, k, &mut rng);
            let secs = t.elapsed().as_secs_f64();
            let centers = original.gather(&seeding.indices);
            let cost = backend.cost(&original, &centers)?;
            if algo == SeedingAlgorithm::FastKMeansPP {
                fast_secs = Some(secs);
            }
            if algo == SeedingAlgorithm::KMeansPP {
                pp_cost = Some(cost);
            }
            rows.push((algo, secs, cost));
        }
        for (algo, secs, cost) in rows {
            println!(
                "| {} | {k} | {secs:.3} | {:.2}x | {cost:.4e} | {:.3} |",
                algo.paper_name(),
                secs / fast_secs.unwrap(),
                cost / pp_cost.unwrap()
            );
        }
    }

    // Lloyd refinement on the best seeding at the largest k: the classic
    // end-to-end k-means pipeline.
    let k = *ks.last().unwrap();
    let mut rng = Pcg64::seed_from(seed);
    let seeding = SeedingAlgorithm::Rejection.run(&q.points, k, &mut rng);
    let centers = original.gather(&seeding.indices);
    let t = Instant::now();
    let refined = lloyd(
        &original,
        &centers,
        &LloydConfig {
            max_iters: 10,
            tol: 1e-5,
        },
        &backend,
    )?;
    println!(
        "\nlloyd refinement (k={k}, backend {}): {} iters in {:.1}s, cost {:.4e} -> {:.4e}",
        backend.name(),
        refined.iterations,
        t.elapsed().as_secs_f64(),
        refined.history.first().unwrap(),
        refined.history.last().unwrap()
    );
    println!(
        "throughput: {:.1}k points/s/iter",
        (original.len() * refined.iterations) as f64 / t.elapsed().as_secs_f64() / 1e3
    );
    Ok(())
}
